"""Real-to-complex subsystem: rfftn/irfftn vs the numpy oracle on 8 host
devices (slab + 2x4 and 4x2 pencil grids), the byte-halving acceptance
check (comm model AND HLO parser both report ~half the c2c bytes), the
pad-to-divisible / pad=False plan-time errors, measured-planner wisdom
keys that never alias r2c with c2c, and the in-process r2c round-trip
property drawn from the shared parametrization in roundtrip_common.
"""

import numpy as np
import pytest

from conftest import run_subprocess
from roundtrip_common import build_plan, roundtrip_given, transform_shape

FAST_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import backends, plan_fft, planner
from repro.core.compat import make_mesh

rng = np.random.default_rng(0)
mesh = make_mesh((8,), ("model",))
P = 8

# --- slab rfft2, every supporting backend (padded transposed layout) ---
x = rng.standard_normal((64, 64)).astype(np.float32)
ref = np.fft.rfft2(x)  # (64, 33)
tol = 1e-4 * np.abs(ref).max()
for name in backends.supporting(P):
    plan = plan_fft((64, 64), mesh, real=True, backend=name)
    assert (plan.hermitian_len, plan.padded_hermitian_len) == (33, 40)
    y = np.asarray(plan.execute(jnp.asarray(x)))
    assert y.shape == (40, 64), (name, y.shape)
    assert np.abs(y[:33] - ref.T).max() < tol, name
    assert np.abs(y[33:]).max() == 0.0, name  # padded rows are exactly zero
    z = np.asarray(plan.inverse(jnp.asarray(y)))
    assert z.dtype == np.float32 and np.abs(z - x).max() < 1e-4, name
print("PASS slab rfft2 backends")

# transpose_back: exact natural numpy shape, one more (truncated) exchange
ptb = plan_fft((64, 64), mesh, real=True, transpose_back=True)
ytb = np.asarray(ptb.execute(jnp.asarray(x)))
assert ytb.shape == ref.shape and np.abs(ytb - ref).max() < tol
assert np.abs(np.asarray(ptb.inverse(jnp.asarray(ytb))) - x).max() < 1e-4
print("PASS slab rfft2 transpose_back")

# --- slab rfft3: exact natural rfftn output ---
x3 = rng.standard_normal((16, 8, 8)).astype(np.float32)
ref3 = np.fft.rfftn(x3)
p3 = plan_fft((16, 8, 8), mesh, ndim=3, real=True)
y3 = np.asarray(p3.execute(jnp.asarray(x3)))
assert y3.shape == ref3.shape
assert np.abs(y3 - ref3).max() < 1e-4 * np.abs(ref3).max()
assert np.abs(np.asarray(p3.inverse(jnp.asarray(y3))) - x3).max() < 1e-4
assert p3.compiles == 2  # cached r2c + c2r executables
print("PASS slab rfft3")

# --- pencil rfft3 on 2x4 AND 4x2 (acceptance grids), odd batch dim ---
xb = rng.standard_normal((3, 16, 8, 8)).astype(np.float32)
refb = np.fft.rfftn(xb, axes=(-3, -2, -1))
refb_rev = refb.transpose(0, 3, 2, 1)  # reversed pencil layout
for pr, pc in ((2, 4), (4, 2)):
    gmesh = make_mesh((pr, pc), ("rows", "cols"))
    pp = plan_fft((3, 16, 8, 8), gmesh, ndim=3, real=True, decomp="pencil")
    h, hp = pp.hermitian_len, pp.padded_hermitian_len
    assert (h, hp) == (5, 8 if pc == 4 else 6), (pr, pc, h, hp)
    yp = np.asarray(pp.execute(jnp.asarray(xb)))
    assert yp.shape == (3, hp, 8, 16), (pr, pc, yp.shape)
    assert np.abs(yp[:, :h] - refb_rev).max() < 1e-4 * np.abs(refb_rev).max(), (pr, pc)
    assert np.abs(yp[:, h:]).max() == 0.0
    zp = np.asarray(pp.inverse(jnp.asarray(yp)))
    assert np.abs(zp - xb).max() < 1e-4, (pr, pc)
    # transpose_back: exact natural rfftn output on the same grid
    pt = plan_fft((3, 16, 8, 8), gmesh, ndim=3, real=True, decomp="pencil",
                  transpose_back=True, backend=("scatter", "bisection"))
    yt = np.asarray(pt.execute(jnp.asarray(xb)))
    assert yt.shape == refb.shape and np.abs(yt - refb).max() < 1e-4 * np.abs(refb).max()
    assert np.abs(np.asarray(pt.inverse(jnp.asarray(yt))) - xb).max() < 1e-4
    print(f"PASS pencil rfft3 {pr}x{pc}")

# --- pencil rfft2: natural padded layout, mixed per-axis backends ---
gmesh = make_mesh((2, 4), ("rows", "cols"))
x2 = rng.standard_normal((5, 16, 16)).astype(np.float32)
ref2 = np.fft.rfft2(x2)
pq = plan_fft((5, 16, 16), gmesh, ndim=2, real=True, decomp="pencil",
              backend=("pairwise_xor", "alltoall"))
h, hp = pq.hermitian_len, pq.padded_hermitian_len
assert (h, hp) == (9, 16)
yq = np.asarray(pq.execute(jnp.asarray(x2)))
assert yq.shape == (5, 16, hp)
assert np.abs(yq[..., :h] - ref2).max() < 1e-4 * np.abs(ref2).max()
assert np.abs(yq[..., h:]).max() == 0.0
assert np.abs(np.asarray(pq.inverse(jnp.asarray(yq))) - x2).max() < 1e-4
print("PASS pencil rfft2")

# --- pad=False: plan-time ValueError naming axis + mesh/grid dim ---
try:
    plan_fft((64, 64), mesh, real=True, pad=False)
    raise SystemExit("expected ValueError")
except ValueError as e:
    assert "Hermitian axis -1" in str(e) and "P=8" in str(e) and "'model'" in str(e), e
try:
    plan_fft((16, 7, 6), mesh, ndim=3, real=True, pad=False)
    raise SystemExit("expected ValueError")
except ValueError as e:
    assert "flattened axes (-2,-1)" in str(e) and "P=8" in str(e), e
try:
    plan_fft((16, 8, 8), gmesh, ndim=3, real=True, decomp="pencil", pad=False)
    raise SystemExit("expected ValueError")
except ValueError as e:
    assert "Hermitian axis -1" in str(e) and "P_col=4" in str(e), e
try:
    plan_fft((16, 16), gmesh, ndim=2, real=True, decomp="pencil", pad=False)
    raise SystemExit("expected ValueError")
except ValueError as e:
    assert "P_row*P_col=8" in str(e), e
# ...and a shape whose Hermitian axis happens to divide plans fine unpadded
ok = plan_fft((64, 126), mesh, real=True, pad=False)  # 126//2+1 = 64
assert ok.hermitian_len == ok.padded_hermitian_len == 64
print("PASS pad errors")

# --- acceptance: r2c slab transpose moves ~half the c2c bytes, per the
# comm model AND the HLO byte parser (both parsers, both backends) ---
from repro.core import comm_model, hlo_analysis
for name in ("alltoall", "scatter"):
    pc_ = plan_fft((256, 256), mesh, backend=name)
    pr_ = plan_fft((256, 256), mesh, backend=name, real=True)
    model_ratio = pr_.comm_bytes() / pc_.comm_bytes()
    ccomp, rcomp = pc_.lower().compile(), pr_.lower().compile()
    parse_ratio = (
        comm_model.parse_collectives(rcomp.as_text(), default_group=P).total_bytes
        / comm_model.parse_collectives(ccomp.as_text(), default_group=P).total_bytes
    )
    hlo_ratio = (
        hlo_analysis.analyze_compiled(rcomp, default_group=P).coll_bytes
        / hlo_analysis.analyze_compiled(ccomp, default_group=P).coll_bytes
    )
    for which, ratio in (("model", model_ratio), ("parse", parse_ratio), ("hlo", hlo_ratio)):
        assert 0.45 < ratio < 0.60, (name, which, ratio)
print("PASS byte halving")

# pencil: model and parser agree on the halved payload too
c3 = plan_fft((16, 8, 64), gmesh, ndim=3, decomp="pencil", backend=("alltoall", "alltoall"))
r3 = plan_fft((16, 8, 64), gmesh, ndim=3, decomp="pencil", real=True,
              backend=("alltoall", "alltoall"))
hr = hlo_analysis.analyze_compiled(r3.lower().compile(), default_group=P).coll_bytes
assert abs(hr - r3.comm_bytes()) < 1e-6 * max(hr, 1.0), (hr, r3.comm_bytes())
assert 0.45 < r3.comm_bytes() / c3.comm_bytes() < 0.62
print("PASS pencil bytes")

# --- measured planner: r2c and c2c wisdom never alias ---
planner.forget_wisdom()
mr = plan_fft((64, 64), mesh, real=True, planner="measure")
mc = plan_fft((64, 64), mesh, planner="measure")
assert mr.backend in mr.measured and mr.measured[mr.backend] == min(mr.measured.values())
keys = sorted(planner._WISDOM)
real_keys = [k for k in keys if "real=1" in k]
c2c_keys = [k for k in keys if "real=" not in k]
# r2c keys carry the real flag; c2c keys keep the pre-real byte format
# (so existing exported wisdom stays valid and pad= can't churn them)
assert len(real_keys) == 1 and len(c2c_keys) == 1, keys
assert "pad=1" in real_keys[0] and "pad" not in c2c_keys[0], keys
again = plan_fft((64, 64), mesh, real=True, planner="measure")
assert again.wisdom_hit and again.backend == mr.backend
print("PASS measured real")

# --- decomp='auto' with real: pencil on a 2-D mesh, slab fallback ---
pa = plan_fft((16, 8, 8), gmesh, ndim=3, real=True, decomp="auto")
assert pa.decomp == "pencil" and pa.real
pb = plan_fft((64, 64), mesh, real=True, decomp="auto")
assert pb.decomp == "slab"
# fuse_dft on real plans: deprecated alias, not an error -- the pipelined
# overlap executor IS the fused real path now (single stacklevel=2 warning)
import warnings
with warnings.catch_warnings(record=True) as rec:
    warnings.simplefilter("always")
    dep_plan = plan_fft((64, 64), mesh, real=True, fuse_dft=True, backend="scatter")
deps = [w for w in rec if issubclass(w.category, DeprecationWarning)]
assert len(deps) == 1 and "pipeline" in str(deps[0].message), [str(w.message) for w in rec]
assert dep_plan.fused and not dep_plan.fuse_dft  # alias resolved to the fused default
yd = np.asarray(dep_plan.execute(jnp.asarray(x)))
assert np.abs(yd[:33] - ref.T).max() < tol
print("PASS real auto")
"""


def test_real_fast_8dev():
    """CI fast job runs this under 8 forced host devices: slab + both
    acceptance pencil grids, byte halving per both parsers, planner."""
    out = run_subprocess(FAST_CODE, devices=8)
    assert out.count("PASS") == 11, out


SLOW_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.core import backends, plan_fft
from repro.core.compat import make_mesh

rng = np.random.default_rng(7)
mesh = make_mesh((8,), ("model",))

# float64 end to end: slab + pencil, fwd vs numpy and round trip
x = rng.standard_normal((16, 8, 10))
ref = np.fft.rfftn(x)
p = plan_fft((16, 8, 10), mesh, ndim=3, real=True, dtype=jnp.float64)
y = np.asarray(p.execute(jnp.asarray(x)))
assert y.dtype == np.complex128 and np.abs(y - ref).max() < 1e-10 * np.abs(ref).max()
z = np.asarray(p.inverse(jnp.asarray(y)))
assert z.dtype == np.float64 and np.abs(z - x).max() < 1e-12
print("PASS f64 slab")

gmesh = make_mesh((2, 4), ("rows", "cols"))
pp = plan_fft((16, 8, 10), gmesh, ndim=3, real=True, decomp="pencil", dtype=jnp.float64)
yp = np.asarray(pp.execute(jnp.asarray(x)))
h = pp.hermitian_len
assert np.abs(yp[:h] - ref.transpose(2, 1, 0)).max() < 1e-10 * np.abs(ref).max()
assert np.abs(np.asarray(pp.inverse(jnp.asarray(yp))) - x).max() < 1e-12
print("PASS f64 pencil")

# full per-axis backend pair matrix for pencil rfft3 round trips (c64)
x32 = x.astype(np.float32)
NAMES = backends.available(kind="shard_map")
for br in NAMES:
    for bc in NAMES:
        if not (backends.get(br).supports(2) and backends.get(bc).supports(4)):
            continue
        q = plan_fft((16, 8, 10), gmesh, ndim=3, real=True, decomp="pencil",
                     backend=(br, bc))
        yq = q.execute(jnp.asarray(x32))
        zq = np.asarray(q.inverse(yq))
        assert np.abs(zq - x32).max() < 1e-4, (br, bc)
print("PASS pair matrix")

# odd last axis through every slab backend
xo = rng.standard_normal((24, 9)).astype(np.float32)
refo = np.fft.rfft2(xo)
for name in backends.supporting(8):
    q = plan_fft((24, 9), mesh, real=True, backend=name, transpose_back=True)
    yo = np.asarray(q.execute(jnp.asarray(xo)))
    assert np.abs(yo - refo).max() < 1e-3 * np.abs(refo).max(), name
    assert np.abs(np.asarray(q.inverse(jnp.asarray(yo))) - xo).max() < 1e-4, name
print("PASS odd last axis")
"""


@pytest.mark.slow
def test_real_slow_8dev():
    out = run_subprocess(SLOW_CODE, devices=8, timeout=1800)
    assert out.count("PASS") == 4, out


# ---------------------------------------------------------------------------
# In-process: r2c round-trip property over the SAME parametrization the
# c2c property test draws (tests/roundtrip_common.py).
# ---------------------------------------------------------------------------


@roundtrip_given
def test_r2c_roundtrip_property(batch, decomp, ndim, wide, last_n):
    import jax.numpy as jnp

    shape = transform_shape(batch, ndim, last_n)
    dtype = jnp.float64 if wide else jnp.float32
    plan = build_plan(shape, decomp, ndim=ndim, dtype=dtype, real=True)
    rng = np.random.default_rng(batch * 100 + ndim * 10 + last_n)
    x = rng.standard_normal(shape).astype(np.float64 if wide else np.float32)
    y = plan.execute(jnp.asarray(x))
    assert jnp.issubdtype(y.dtype, jnp.complexfloating)
    assert y.shape == plan.spectrum_shape(), (y.shape, plan.spectrum_shape())
    z = np.asarray(plan.inverse(y))
    assert z.shape == x.shape and not np.iscomplexobj(z)
    assert np.abs(z - x).max() < 1e-4 * max(np.abs(x).max(), 1.0), (
        decomp, ndim, batch, last_n, wide,
    )


def test_lower_shares_executable_cache_with_execution():
    """lower()/roofline() of a real plan's c2r side must cache under the
    spectrum dtype, so a later inverse() reuses the wrapper instead of
    compiling a second one (the PR-2 lower-reuses-cache contract)."""
    import jax.numpy as jnp

    plan = build_plan((8, 10), "slab", real=True)
    plan.lower(inverse=True)
    assert plan.compiles == 1
    x = jnp.zeros((8, 10), jnp.float32)
    y = plan.execute(x)
    assert plan.compiles == 2
    plan.inverse(y)  # same wrapper as the lowered c2r side
    assert plan.compiles == 2, sorted(plan._cache)


def test_spectral_axes_contract():
    """The layout contract the apps build on: orig-axis bookkeeping,
    Hermitian flags, and padding exactly where the axis stays sharded."""
    import jax.numpy as jnp

    plan = build_plan((8, 10), "slab", real=True)  # P=1: Hp == H
    axes = plan.spectral_axes()
    assert [a.orig for a in axes] == [-1, -2]  # slab 2-D spectrum is transposed
    assert axes[0].half and not axes[1].half
    assert axes[0].n == 10 and axes[0].n_out == 6
    assert plan.spectrum_shape() == (6, 8)

    plan3 = build_plan((4, 6, 8), "pencil", ndim=3, real=True)
    axes3 = plan3.spectral_axes()
    assert [a.orig for a in axes3] == [-1, -2, -3]  # reversed pencil layout
    assert axes3[0].half and axes3[0].n_out == 5
    assert plan3.spectrum_shape() == (5, 6, 4)

    c2c = build_plan((4, 6, 8), "pencil", ndim=3, dtype=jnp.complex64)
    assert [a.half for a in c2c.spectral_axes()] == [False] * 3
    assert c2c.spectrum_shape() == (8, 6, 4)
