"""Pipelined overlap executor: fused (chunk-streamed, compute-in-flight)
exchanges must match their unfused monolithic twins to tight tolerance
for every streaming backend across slab/pencil x c2c/r2c x fwd/inv --
the 8-device subprocess sweep draws its batch/last-axis field from
tests/roundtrip_common.py. Plus: the chunk_fn-on-monolithic-backend
error regression, sub-chunking arithmetic, the overlap-aware cost model
(fused vs unfused, n_chunks), and measured-planner variant plumbing
(old-format wisdom can never alias a fused entry).
"""

import pytest

from conftest import run_subprocess
from roundtrip_common import BATCH_VALUES

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import CommParams, backends, comm_model, plan_fft, planner  # noqa: E402
from repro.core import transpose as tr  # noqa: E402
from repro.core.compat import make_mesh, make_mesh_1d, shard_map  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402


# ---------------------------------------------------------------------------
# 8-device sweep: fused == unfused for every streaming backend across
# slab/pencil x c2c/r2c x fwd/inv (tolerance: the fused cross-rank DFT
# uses tabulated matrices -- a few ulps of the c64 transform, orders
# below the oracle tolerances the numerics suites use)
# ---------------------------------------------------------------------------

FUSED_SWEEP_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import backends, plan_fft
from repro.core.compat import make_mesh

rng = np.random.default_rng(0)
BATCHES = __BATCHES__
STREAMING = [n for n in backends.available(kind="shard_map")
             if backends.get(n).supports_chunk_fn]
assert STREAMING, "no streaming backends registered?"

mesh = make_mesh((8,), ("model",))
gmesh = make_mesh((2, 4), ("rows", "cols"))


def compare(tag, plan_kw, backend, pipelines=("auto", 24), inv=True):
    batch = BATCHES[hash(tag) % len(BATCHES)]
    dims = plan_kw.pop("dims")
    shape = ((batch,) + dims) if plan_kw.get("ndim", 2) > 1 else dims
    kw = dict(plan_kw, global_shape=shape)
    base = plan_fft(backend=backend, pipeline=False, **kw)
    assert not base.fused
    if base.real:
        x = rng.standard_normal(shape).astype(np.float32)
    else:
        x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
    y_ref = np.asarray(base.execute(jnp.asarray(x)))
    scale = max(np.abs(y_ref).max(), 1.0)
    for pipe in pipelines:
        fused = plan_fft(backend=backend, pipeline=pipe, **kw)
        assert fused.fused, (tag, backend, pipe)
        y = np.asarray(fused.execute(jnp.asarray(x)))
        err = np.abs(y - y_ref).max() / scale
        assert err < 5e-5, (tag, backend, pipe, "fwd", err)
        if inv:
            z = np.asarray(fused.inverse(jnp.asarray(y)))
            z_ref = np.asarray(base.inverse(jnp.asarray(y_ref)))
            zerr = np.abs(z - z_ref).max() / max(np.abs(x).max(), 1.0)
            assert zerr < 5e-5, (tag, backend, pipe, "inv", zerr)
        # the model half of the acceptance check: the fused variant of
        # this exact problem predicts cheaper than its unfused twin
        nc = fused.n_chunks
        pf = fused.predict(fused=True, n_chunks=nc)[fused.backend]
        pu = fused.predict(fused=False, n_chunks=nc)[fused.backend]
        assert pf < pu, (tag, backend, pipe, pf, pu)
    print(f"PASS {tag}")


for b in STREAMING:
    compare(f"slab-fft2-{b}", dict(dims=(16, 16), mesh=mesh), b)
    compare(f"slab-rfft2-{b}", dict(dims=(24, 17), mesh=mesh, real=True), b)
compare("slab-fft3", dict(dims=(16, 8, 8), mesh=mesh, ndim=3), "scatter")
compare("slab-fft1d", dict(dims=(4096,), mesh=mesh, ndim=1), "scatter", inv=False)
compare("slab-rfft2-tb", dict(dims=(16, 16), mesh=mesh, real=True, transpose_back=True),
        "pairwise_xor")
compare("slab-rfft3", dict(dims=(16, 8, 8), mesh=mesh, ndim=3, real=True), "scatter")
for b in STREAMING:
    compare(f"pencil-fft3-{b}", dict(dims=(16, 8, 8), mesh=gmesh, ndim=3, decomp="pencil"),
            (b, b))
compare("pencil-fft3-mixed", dict(dims=(16, 8, 8), mesh=gmesh, ndim=3, decomp="pencil"),
        ("scatter", "bisection"))
compare("pencil-fft2", dict(dims=(16, 16), mesh=gmesh, ndim=2, decomp="pencil"),
        ("scatter", "scatter"))
compare("pencil-rfft3", dict(dims=(16, 8, 8), mesh=gmesh, ndim=3, decomp="pencil", real=True),
        ("scatter", "scatter"))
compare("pencil-rfft3-tb", dict(dims=(16, 8, 8), mesh=gmesh, ndim=3, decomp="pencil",
        real=True, transpose_back=True), ("pairwise_xor", "scatter"))
compare("pencil-rfft2", dict(dims=(16, 16), mesh=gmesh, ndim=2, decomp="pencil", real=True),
        ("scatter", "pairwise_xor"))
# the Pallas fused twiddle+pack kernel rides the per-chunk callback
compare("slab-fft2-pallas", dict(dims=(16, 16), mesh=mesh, local_impl="pallas"),
        "scatter", pipelines=("auto",))
"""

def test_fused_matches_unfused_8dev():
    """CI fast job runs this under the forced-8-device harness."""
    code = FUSED_SWEEP_CODE.replace("__BATCHES__", repr(tuple(BATCH_VALUES)))
    out = run_subprocess(code, devices=8, timeout=1800)
    n_streaming = len(
        [n for n in backends.available(kind="shard_map")
         if backends.get(n).supports_chunk_fn]
    )
    # 2 slab tags per streaming backend + 1 pencil tag each + 10 fixed tags
    expected = 3 * n_streaming + 10
    assert out.count("PASS") == expected, out


MEASURED_VARIANTS_CODE = r"""
import json
from repro.core import plan_fft, planner
from repro.core.compat import make_mesh

mesh = make_mesh((8,), ("model",))
planner.forget_wisdom()
mp = plan_fft((16, 16), mesh, planner="measure", timer=lambda plan: 1.0)
# the field includes (backend, n_chunks, fused) triples
assert any(k.endswith("@u") for k in mp.measured), sorted(mp.measured)
assert any("@f16" in k for k in mp.measured), sorted(mp.measured)
assert "scatter" in mp.measured  # plain = default fused resolution
(key,) = json.loads(planner.export_wisdom())["entries"]
assert "@u" in key  # variant ids reach the wisdom key

# an old-format (pre-pipeline) wisdom entry -- plain candidate names --
# keys differently, so it can never be replayed as (alias) a fused plan
old_names = tuple(planner.candidate_backends(8))
old_key = planner.wisdom_key(
    (16, 16), 2, "complex64", 8, old_names, planner.device_kind(mesh),
    opts="mesh=model8,decomp=slab,ax=model,dir=forward,impl=jnp,fuse=0,tb=0",
)
assert old_key != key
planner._WISDOM[old_key] = {"backend": "alltoall",
                            "timings": {n: 0.5 for n in old_names}}
again = plan_fft((16, 16), mesh, planner="measure", timer=lambda plan: 2.0)
assert again.wisdom_hit  # hits ITS OWN (variant) entry...
assert set(again.measured) == set(mp.measured)  # ...never the old one

# a variant winner is buildable from wisdom (replay path parses the id)
vid = sorted(k for k in mp.measured if k.endswith("@u"))[0]
planner._WISDOM[key]["backend"] = vid
replay = plan_fft((16, 16), mesh, planner="measure", timer=lambda plan: 3.0)
assert replay.wisdom_hit and replay.backend == vid and not replay.fused

# pinned pipeline=False: plain candidates, distinct wisdom key
planner.forget_wisdom()
off = plan_fft((16, 16), mesh, planner="measure", pipeline=False,
               timer=lambda plan: 1.0)
assert set(off.measured) == set(old_names), sorted(off.measured)
(key_off,) = json.loads(planner.export_wisdom())["entries"]
assert "pipe=False" in key_off
print("PASS measured variants")
"""


def test_measured_planner_races_variants_8dev():
    out = run_subprocess(MEASURED_VARIANTS_CODE, devices=8)
    assert out.count("PASS") == 1, out


SUBCHUNK_TRANSPOSE_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import repro.core.transpose as tr
from repro.core.compat import make_mesh, shard_map

mesh = make_mesh((8,), ("model",))
p, r, C = 8, 4, 64
rng = np.random.default_rng(3)
x = (rng.standard_normal((p * r, C)) + 1j * rng.standard_normal((p * r, C))).astype(np.complex64)


def run(strategy, chunk_fn=None, n_chunks=None):
    def fn(xl):
        return tr.distributed_transpose(
            xl, "model", strategy=strategy, chunk_fn=chunk_fn, n_chunks=n_chunks
        )
    return np.asarray(
        shard_map(fn, mesh=mesh, in_specs=P("model", None), out_specs=P("model", None))(
            jnp.asarray(x)
        )
    )

ref = run("alltoall")
assert np.abs(ref - x.T).max() == 0.0

# sub-chunked transport alone must be exact (pure data movement)
for strategy in ("scatter", "pairwise_xor"):
    for nc in (None, 16, 32, 64):
        got = run(strategy, n_chunks=nc)
        assert np.abs(got - ref).max() == 0.0, (strategy, nc)
print("PASS subchunk transport")

# 2-arg chunk_fn under sub-chunking: applied to the REASSEMBLED peer
# block (transport-only pipelining), so any per-peer function matches
got = run("scatter", chunk_fn=lambda c, s: c * (s.astype(np.complex64) + 1), n_chunks=32)
scale = np.repeat(np.arange(p) + 1, r)[None, :]  # per source block of output cols
exp_local = ref.reshape(p, C // p, p * r) * scale[None, ...]
assert np.abs(got - exp_local.reshape(got.shape)).max() < 1e-6
print("PASS 2-arg chunk_fn")

# 3-arg chunk_fn: per-sub-chunk offsets land where they should
q = tr.subchunks_per_peer(r, p, 16)
assert q == 2
rq = r // q
got = run("scatter", chunk_fn=lambda c, s, off: c + off, n_chunks=16)
off_row = np.concatenate([np.full(rq, t * rq) for t in range(q)])  # within one peer block
exp = ref + np.tile(off_row, p)[None, :]
assert np.abs(got - exp).max() < 1e-6
print("PASS 3-arg chunk_fn offsets")

# the fused path keeps the plain transpose's friendly divisibility error
# (not a reshape blow-up inside _split_chunks)
bad = jnp.zeros((32, 60), jnp.complex64)  # 60 % 8 != 0
def bad_fn(xl):
    return tr.transpose_then_fft(xl, "model", strategy="scatter", fused=True)
try:
    shard_map(bad_fn, mesh=mesh, in_specs=P("model", None), out_specs=P("model", None))(bad)
    raise SystemExit("expected ValueError")
except ValueError as e:
    assert "not divisible" in str(e), e
print("PASS fused divisibility error")
"""


def test_subchunked_transpose_semantics_8dev():
    out = run_subprocess(SUBCHUNK_TRANSPOSE_CODE, devices=8)
    assert out.count("PASS") == 4, out


# ---------------------------------------------------------------------------
# In-process regressions
# ---------------------------------------------------------------------------


def test_chunk_fn_on_monolithic_backend_still_raises_naming_streaming():
    """The transpose.py guard: chunk_fn on a non-streaming backend must
    fail loudly, listing the backends that CAN stream."""
    mesh = make_mesh_1d(1)

    def fn(xl):
        return tr.distributed_transpose(
            xl, "model", strategy="alltoall", chunk_fn=lambda c, s: c
        )

    with pytest.raises(ValueError) as ei:
        shard_map(fn, mesh=mesh, in_specs=P("model"), out_specs=P("model"))(
            jnp.zeros((4, 4), jnp.complex64)
        )
    msg = str(ei.value)
    assert "chunk-streaming" in msg
    for name in backends.available():
        if backends.get(name).supports_chunk_fn:
            assert name in msg, (name, msg)


def test_subchunks_per_peer_divides_rows():
    assert tr.subchunks_per_peer(8, 4, None) == 1
    assert tr.subchunks_per_peer(8, 4, 4) == 1  # n_chunks <= p: classic
    assert tr.subchunks_per_peer(8, 4, 8) == 2
    assert tr.subchunks_per_peer(8, 4, 16) == 4
    assert tr.subchunks_per_peer(6, 4, 16) == 3  # snaps to a divisor of r
    assert tr.subchunks_per_peer(7, 4, 16) == 1  # prime rows: no split <= target
    assert tr.subchunks_per_peer(4, 4, 10 ** 9) == 4  # capped at r


def test_chunk_fn_arity_detection():
    assert tr._chunk_fn_arity(lambda c, s: c) == 2
    assert tr._chunk_fn_arity(lambda c, s, off: c) == 3
    assert tr._chunk_fn_arity(lambda *a: a[0]) == 3

    def kw_only(c, s, *, off=0):
        return c

    assert tr._chunk_fn_arity(kw_only) == 2


def test_cost_model_overlap_and_n_chunks():
    m, p = 8 * 2**20, 8
    prm = CommParams()
    # n_chunks=None/p reduces to the classic formula
    assert comm_model.t_scatter_ring(m, p, prm, 1e-4) == comm_model.t_scatter_ring(
        m, p, prm, 1e-4, n_chunks=p
    )
    # sub-chunking pays (q-1)(p-1) extra alphas when compute is free...
    base = comm_model.t_scatter_ring(m, p, prm)
    sub = comm_model.t_scatter_ring(m, p, prm, n_chunks=2 * p)
    assert abs(sub - (base + (p - 1) * prm.alpha_s)) < 1e-12
    # ...but hides compute at finer grain when compute dominates
    per_msg = prm.alpha_s + (m / p) / prm.beta_bytes_s
    heavy = 10 * per_msg
    assert comm_model.t_scatter_ring(m, p, prm, heavy, n_chunks=8 * p) < (
        comm_model.t_scatter_ring(m, p, prm, heavy)
    )
    # fused=False serializes the stage compute on streaming backends too
    b = backends.get("scatter")
    assert b.cost(m, p, prm, heavy, fused=False) > b.cost(m, p, prm, heavy, fused=True)
    assert b.cost(m, p, prm, heavy, fused=False) == pytest.approx(
        comm_model.t_scatter_ring(m, p, prm) + p * heavy
    )
    # monolithic backends are indifferent to the flag
    a = backends.get("alltoall")
    assert a.cost(m, p, prm, heavy, fused=False) == a.cost(m, p, prm, heavy, fused=True)
    # model-side twin of the executed sub-chunk count
    assert comm_model.effective_chunks(8, None) == 8
    assert comm_model.effective_chunks(8, 24) == 24
    assert comm_model.effective_chunks(8, 20) == 24  # ceil to whole sub-chunks


def test_plan_predict_reports_fused_vs_unfused():
    """P=1 plan, but the report path exercises the full plumbing: the
    fused variant must never predict costlier than the unfused one, and
    explicit n_chunks must reach the model."""
    mesh = make_mesh_1d(1)
    plan = plan_fft((32, 32), mesh, backend="scatter")
    cc = 1e-4
    f = plan.predict(chunk_compute_s=cc, fused=True)
    u = plan.predict(chunk_compute_s=cc, fused=False)
    assert set(f) == set(u)
    assert all(f[k] <= u[k] for k in f)
    n = plan.predict(chunk_compute_s=cc, fused=True, n_chunks=64)
    assert set(n) == set(f)


def test_pipeline_argument_validation_and_resolution():
    mesh = make_mesh_1d(1)
    with pytest.raises(ValueError, match="pipeline"):
        plan_fft((16, 16), mesh, pipeline="eager")
    with pytest.raises(ValueError, match="pipeline"):
        plan_fft((16, 16), mesh, pipeline=-3)
    p16 = plan_fft((16, 16), mesh, backend="scatter", pipeline=16)
    assert p16.n_chunks == 16 and p16.fused is False  # P=1: nothing to stream
    off = plan_fft((16, 16), mesh, backend="scatter", pipeline=0)
    assert off.fused is False and off.n_chunks is None
    auto = plan_fft((16, 16), mesh, backend="scatter", pipeline=True)
    assert auto.pipeline == "auto"
    # 1 == True in Python: an explicit one-chunk pipeline must NOT alias
    # to "auto" (and must still conflict with a variant suffix)
    one = plan_fft((16, 16), mesh, backend="scatter", pipeline=1)
    assert one.pipeline == 1 and one.pipeline is not True and one.n_chunks == 1
    with pytest.raises(ValueError, match="both specify"):
        plan_fft((16, 16), mesh, backend="scatter@u", pipeline=1)


def test_backend_variant_id_round_trips_through_plan_fft():
    """A measured variant winner's Plan.backend (e.g. 'scatter@u') must
    be re-plannable: the suffix is parsed as a pipeline override."""
    mesh = make_mesh_1d(1)
    p = plan_fft((16, 16), mesh, backend="scatter@u")
    assert p.backend == "scatter" and p.pipeline is False and not p.fused
    p2 = plan_fft((16, 16), mesh, backend="scatter@f16")
    assert p2.backend == "scatter" and p2.n_chunks == 16
    gmesh = make_mesh((1, 1), ("rows", "cols"))
    pp = plan_fft((8, 8), gmesh, decomp="pencil", backend="scatter+bisection@u")
    assert pp.backend == "scatter+bisection" and pp.pipeline is False
    with pytest.raises(ValueError, match="both specify"):
        plan_fft((16, 16), mesh, backend="scatter@u", pipeline=16)


def test_backend_variant_id_round_trips_through_measured_planner():
    """planner='measure' with a pinned variant id races exactly that
    candidate (the re-plan path for a measured winner's Plan.backend)."""
    mesh = make_mesh_1d(1)
    planner.forget_wisdom()
    calls = []

    def timer(plan):
        calls.append(plan.backend)
        return 1.0

    mp = plan_fft((16, 16), mesh, planner="measure", backend="scatter@u", timer=timer)
    assert calls == ["scatter@u"] and mp.backend == "scatter@u"
    assert mp.pipeline is False and not mp.fused
    gmesh = make_mesh((1, 1), ("rows", "cols"))
    mpp = plan_fft((8, 8), gmesh, decomp="pencil", planner="measure",
                   backend="scatter+bisection@f8", timer=timer)
    assert mpp.backend == "scatter+bisection@f8" and mpp.n_chunks == 8
    with pytest.raises(ValueError, match="both specify"):
        plan_fft((16, 16), mesh, planner="measure", backend="scatter@u",
                 pipeline=16, timer=timer)


def test_fuse_dft_disabled_by_explicit_pipeline_off():
    """One knob disables fusion everywhere: pipeline=False wins over the
    legacy fuse_dft alias at both the plan and the config layer."""
    mesh = make_mesh_1d(1)
    on = plan_fft((16, 16), mesh, backend="scatter", fuse_dft=True)
    assert on._cfg.fuse_dft is True  # legacy alias flows through by default
    off = plan_fft((16, 16), mesh, backend="scatter", fuse_dft=True, pipeline=False)
    assert off.fused is False and off._cfg.fuse_dft is False
    assert off._cfg.fused is False


def test_predict_candidate_honours_race_pipeline():
    mesh = make_mesh_1d(1)
    plan = plan_fft((32, 32), mesh, backend="scatter", pipeline=False)
    # plain candidate measured under pipeline=False models unfused
    assert planner.predict_candidate(plan, "scatter", pipeline=False) == pytest.approx(
        plan.predict(fused=False)["scatter"]
    )
    # explicit variant suffix still wins over the race context
    assert planner.predict_candidate(plan, "scatter@f16", pipeline=False) == pytest.approx(
        plan.predict(fused=True, n_chunks=16)["scatter"]
    )
    assert planner.variant_id("scatter", None) == "scatter"
    assert planner.variant_id("scatter", False) == "scatter@u"
    assert planner.variant_id("a+b", 8) == "a+b@f8"


def test_predict_candidate_matches_variant_resolution():
    mesh = make_mesh_1d(1)
    plan = plan_fft((32, 32), mesh, backend="scatter")
    assert planner.predict_candidate(plan, "scatter") == pytest.approx(
        plan.predict(fused=True)["scatter"]
    )
    assert planner.predict_candidate(plan, "scatter@u") == pytest.approx(
        plan.predict(fused=False)["scatter"]
    )
    assert planner.predict_candidate(plan, "scatter@f16") == pytest.approx(
        plan.predict(fused=True, n_chunks=16)["scatter"]
    )
    with pytest.raises(ValueError, match="variant"):
        planner.parse_variant("scatter@turbo")
