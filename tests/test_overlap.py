"""Decomposed-collective overlap layer: ring all-gather / reduce-scatter /
scatter-reduce / collective matmul vs dense references (8 host devices)."""

import pytest

from conftest import run_subprocess

CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.compat import make_mesh, shard_map
from repro.core import (collective_matmul_ag, ring_all_gather,
                        ring_reduce_scatter, ring_scatter_reduce)

mesh = make_mesh((8,), ("model",))
rng = np.random.default_rng(0)
def run(fn, x, si, so):
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=si, out_specs=so, check_vma=False))(x)

v = rng.standard_normal((8, 16)).astype(np.float32)
g = run(lambda a: ring_all_gather(a, "model", axis=0), jnp.asarray(v), P("model", None), P(None, None))
assert np.allclose(np.asarray(g), v)
print("PASS ring_all_gather")

rs = run(lambda a: ring_reduce_scatter(a, "model", axis=-1), jnp.asarray(v), P("model", None), P("model", None))
assert np.allclose(np.asarray(rs), v.sum(0).reshape(8, 2), atol=1e-5)
print("PASS ring_reduce_scatter")

k, n = 32, 16
xm = rng.standard_normal((4, k)).astype(np.float32)
w = rng.standard_normal((k, n)).astype(np.float32)
cm = run(lambda a: collective_matmul_ag(a, jnp.asarray(w), "model"),
         jnp.asarray(xm), P(None, "model"), P(None, None))
assert np.allclose(np.asarray(cm), xm @ w, atol=1e-4)
print("PASS collective_matmul_ag")

# scatter-reduce: sum over sources of chunk_fn(chunk destined to me)
x = rng.standard_normal((8, 32)).astype(np.float32)
def body(a):
    return ring_scatter_reduce(a, "model", lambda c, src: c * 1.0, split_axis=-1)
got = run(body, jnp.asarray(x), P("model", None), P("model", None))
# rank r receives chunk r (cols 4r:4r+4) from every source row -> sum over rows
exp = x.sum(0).reshape(8, 4)
assert np.allclose(np.asarray(got), exp, atol=1e-5)
print("PASS ring_scatter_reduce")

# gradient flows through the ring (ppermute transpose)
def loss(a):
    def f(al):
        return (ring_all_gather(al, "model", axis=0) ** 2).sum()
    return shard_map(f, mesh=mesh, in_specs=P("model", None), out_specs=P(), check_vma=False)(a)
gr = jax.grad(loss)(jnp.asarray(v))
assert np.allclose(np.asarray(gr), 2 * v, atol=1e-4)
print("PASS ring gradient")
"""


@pytest.mark.slow
def test_overlap_primitives_8dev():
    out = run_subprocess(CODE, devices=8)
    assert out.count("PASS") == 5, out
