"""Observability subsystem tests: TraceRecorder exports (Chrome-trace
schema, JSONL round-trip, multi-process adoption), the spec simulation
behind the segmented trace-mode executor, the alpha/beta online re-fit
from observed Exchange spans, the wisdom observed-timings channel, and
-- in an 8-device subprocess -- the acceptance contract: traced
execution stamps exactly one Exchange span per schedule Exchange stage
whose wire bytes match ``schedule_comm_bytes`` exactly, ``Plan.profile``
returns one observed row per schedule stage, and the untraced hot path
compiles to byte-identical HLO before and after profiling."""

import dataclasses
import json
import math
import sys
import types

import pytest

from conftest import REPO, run_subprocess

if REPO not in sys.path:
    sys.path.insert(0, REPO)

from repro.core import planner  # noqa: E402
from repro.core import schedule as sch  # noqa: E402
from repro.core.comm_model import (  # noqa: E402
    CommParams,
    exchange_fit_terms,
    payload_class,
)
from repro.obs import Span, TraceRecorder, merge_traces  # noqa: E402
from test_schedule import snapshot_cases  # noqa: E402


# ---------------------------------------------------------------------------
# TraceRecorder: recording + exports
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


def test_span_contextmanager_and_fake_clock():
    clk = FakeClock()
    rec = TraceRecorder(clk)
    with rec.span("fft", cat="stage", stage="LocalFFT") as sp:
        clk.t += 0.25
        sp.args["extra"] = 7  # annotatable before the block exits
    assert len(rec.spans) == 1
    s = rec.spans[0]
    assert s.name == "fft" and s.t0 == 0.0 and s.dur == 0.25
    assert s.args == {"stage": "LocalFFT", "extra": 7}
    # spans exit even when the body raises
    with pytest.raises(RuntimeError):
        with rec.span("boom"):
            clk.t += 1.0
            raise RuntimeError("x")
    assert [s.name for s in rec.spans] == ["fft", "boom"]
    assert rec.total_seconds() == pytest.approx(1.25)


def test_mark_and_exchange_filter():
    clk = FakeClock()
    rec = TraceRecorder(clk)
    rec.add_span("a", 0.0, 0.1, cat="stage")
    m = rec.mark()
    rec.add_span("b", 0.1, 0.2, cat="exchange", args={"backend": "scatter"})
    rec.add_span("c", 0.3, 0.1, cat="stage")
    assert [s.name for s in rec.spans_since(m)] == ["b", "c"]
    assert [s.name for s in rec.exchange_spans()] == ["b"]


def test_chrome_trace_schema():
    """Every exported event carries the fields the Perfetto/Chrome JSON
    loaders require: complete ('X') events have name/ts/dur/pid/tid/args
    with microsecond times, counters are 'C', process names 'M'."""
    clk = FakeClock()
    rec = TraceRecorder(clk, pid=3)
    rec.set_process_name(3, "harness")
    with rec.span("row:x", cat="exchange", backend="scatter", wire_bytes=1024.0):
        clk.t += 0.001
    rec.counter("queue", depth=4, inflight=1)
    doc = rec.to_chrome_trace()
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert {e["ph"] for e in events} == {"X", "C", "M"}
    for e in events:
        assert isinstance(e["name"], str) and isinstance(e["pid"], int)
        assert isinstance(e["tid"], int) and isinstance(e["args"], dict)
        if e["ph"] in ("X", "C"):
            assert isinstance(e["ts"], (int, float))
        if e["ph"] == "X":
            assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
    (x,) = [e for e in events if e["ph"] == "X"]
    assert x["ts"] == 0.0 and x["dur"] == pytest.approx(1000.0)  # microseconds
    assert x["args"]["wire_bytes"] == 1024.0
    (m,) = [e for e in events if e["ph"] == "M"]
    assert m["name"] == "process_name" and m["args"] == {"name": "harness"}
    json.dumps(doc)  # must be serialisable as-is


def test_jsonl_roundtrip(tmp_path):
    clk = FakeClock()
    rec = TraceRecorder(clk)
    rec.add_span("a", 0.0, 0.5, cat="exchange", args={"backend": "bisection", "p": 8})
    rec.counter("pool", hits=2.0)
    path = tmp_path / "t.jsonl"
    rec.write_jsonl(str(path))
    back = TraceRecorder.from_jsonl(str(path))
    assert len(back.spans) == 1 and len(back.counters) == 1
    s = back.spans[0]
    assert (s.name, s.t0, s.dur, s.cat) == ("a", 0.0, 0.5, "exchange")
    assert s.args == {"backend": "bisection", "p": 8}
    assert back.counters[0].values == {"hits": 2.0}


def test_adopt_rehomes_foreign_events():
    rec = TraceRecorder(FakeClock())
    rec.add_span("local", 0.0, 0.1)
    foreign = [
        {"name": "sub", "ph": "X", "ts": 0.0, "dur": 5.0, "pid": 0, "tid": 0, "args": {}}
    ]
    rec.adopt(foreign, name="fft_measure p=8")
    doc = rec.to_chrome_trace()
    sub = [e for e in doc["traceEvents"] if e.get("name") == "sub"]
    assert len(sub) == 1 and sub[0]["pid"] != rec.pid  # re-homed, not clobbered
    names = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert names and names[0]["args"]["name"] == "fft_measure p=8"
    assert foreign[0]["pid"] == 0  # caller's event dict untouched


def test_merge_traces_one_pid_per_recorder():
    a, b = TraceRecorder(FakeClock()), TraceRecorder(FakeClock())
    a.add_span("a", 0.0, 0.1)
    b.add_span("b", 0.0, 0.2)
    out = merge_traces([a, b], names=["first", "second"])
    events = out.to_chrome_trace()["traceEvents"]
    pid = {e["name"]: e["pid"] for e in events if e["ph"] == "X"}
    assert pid["a"] != pid["b"]
    meta = {e["pid"]: e["args"]["name"] for e in events if e["ph"] == "M"}
    assert meta[pid["a"]] == "first" and meta[pid["b"]] == "second"


# ---------------------------------------------------------------------------
# Spec simulation (what makes per-stage segmentation shard-safe)
# ---------------------------------------------------------------------------


def test_simulate_specs_over_every_golden_schedule():
    """The symbolic spec walk must accept every schedule the builders can
    emit (the golden snapshot grid) and land exactly on the schedule's
    declared out_tail -- otherwise the trace-mode executor would reshard
    between segments."""
    n_checked = 0
    for key, kw in sorted(snapshot_cases().items()):
        s = sch.build_schedule(**kw)
        if s.global_backend is not None:
            continue  # GSPMD reference: traced as one whole-transform span
        specs = sch.simulate_specs(s, kw["ndim"])
        assert len(specs) == len(s.stages) + 1, key
        assert specs[0][-len(s.in_tail):] == s.in_tail, key
        assert specs[-1][-len(s.out_tail):] == s.out_tail, key
        n_checked += 1
    assert n_checked >= 30  # the grid is the whole pipeline surface


def test_simulate_specs_rejects_mislaid_exchange():
    s = sch.build_schedule(
        global_shape=(16, 16), ndim=2, decomp="slab", axis_name="x",
        p=4, backend="scatter",
    )
    bad_stages = tuple(
        dataclasses.replace(st, axis="nope") if isinstance(st, sch.Exchange) else st
        for st in s.stages
    )
    bad = dataclasses.replace(s, stages=bad_stages)
    with pytest.raises(ValueError, match="mesh axis"):
        sch.simulate_specs(bad, 2)


# ---------------------------------------------------------------------------
# Online alpha/beta refinement from observed Exchange spans
# ---------------------------------------------------------------------------


def _span(backend, p, block_bytes, dur, n_chunks=None):
    args = {"backend": backend, "p": p, "block_bytes": float(block_bytes),
            "wire_bytes": float(block_bytes) * (1 - 1 / p)}
    if n_chunks is not None:
        args["n_chunks"] = n_chunks
    return {"cat": "exchange", "args": args, "dur": dur}


def test_refine_online_recovers_synthetic_constants():
    alpha, beta = 2e-6, 1e10
    spans = []
    for block in (100 * 1024, 400 * 1024, 1 << 20):
        msgs, fit_bytes = exchange_fit_terms("scatter", 8, float(block), 8)
        spans.append(_span("scatter", 8, block, alpha * msgs + fit_bytes / beta, 8))
    assert len({payload_class(s["args"]["wire_bytes"]) for s in spans}) == 1
    base = CommParams()
    fits = base.refine_online(spans)
    key = ("scatter", payload_class(spans[0]["args"]["wire_bytes"]))
    assert key in fits and ("*", "*") in fits
    fitted = fits[key]
    assert fitted is not base  # frozen: a new instance, self untouched
    assert fitted.alpha_s == pytest.approx(alpha, rel=1e-6)
    assert fitted.beta_bytes_s == pytest.approx(beta, rel=1e-6)
    pooled = fits[("*", "*")]
    assert pooled.alpha_s == pytest.approx(alpha, rel=1e-6)


def test_refine_online_degenerate_keeps_defaults():
    base = CommParams()
    # one span: under min_spans -> keep the frozen constants
    fits = base.refine_online([_span("scatter", 8, 1 << 20, 1e-3, 8)])
    assert fits[("*", "*")] is base
    # rank-1 system (identical sizes) -> unidentifiable, keep constants
    fits = base.refine_online([_span("alltoall", 8, 1 << 20, 1e-3)] * 3)
    assert fits[("alltoall", payload_class((1 << 20) * (1 - 1 / 8)))] is base
    # junk spans are skipped, not crashed on
    fits = base.refine_online([{"cat": "exchange", "args": {}, "dur": -1}])
    assert fits[("*", "*")] is base


def test_refine_online_accepts_trace_recorder():
    rec = TraceRecorder(FakeClock())
    alpha, beta = 5e-6, 2e10
    for block in (128 * 1024, 512 * 1024, 1 << 21):
        msgs, fit_bytes = exchange_fit_terms("bisection", 8, float(block))
        rec.add_span(
            "row:x", 0.0, alpha * msgs + fit_bytes / beta, cat="exchange",
            args={"backend": "bisection", "p": 8, "block_bytes": float(block),
                  "wire_bytes": float(block) * (1 - 1 / 8)},
        )
    rec.add_span("LocalFFT", 0.0, 9.9, cat="stage")  # must not pollute the fit
    fits = CommParams().refine_online(rec)
    pooled = fits[("*", "*")]
    assert pooled.alpha_s == pytest.approx(alpha, rel=1e-6)
    assert pooled.beta_bytes_s == pytest.approx(beta, rel=1e-6)


def test_exchange_fit_terms_shapes():
    # ring: (p-1)*q messages of the wire payload
    msgs, b = exchange_fit_terms("scatter", 8, 1024.0, 8)
    assert msgs == 7.0 and b == pytest.approx(1024.0 * 7 / 8)
    # bisection: log2(p) rounds of half the block
    msgs, b = exchange_fit_terms("bisection", 8, 1024.0)
    assert msgs == 3.0 and b == pytest.approx(3 * 512.0)
    # single shard: no communication
    assert exchange_fit_terms("scatter", 1, 1024.0) == (0.0, 0.0)
    # unknown backends take the one-phase all-to-all shape
    assert exchange_fit_terms("mystery", 4, 1024.0)[0] == 1.0


# ---------------------------------------------------------------------------
# Wisdom observed-timings channel
# ---------------------------------------------------------------------------


def _fake_plan(key, backend):
    return types.SimpleNamespace(wisdom_key=key, backend=backend)


def test_record_observed_running_mean_and_reargmin():
    planner.forget_wisdom()
    key = ("test", "obs")
    planner._WISDOM[key] = {
        "timings": {"scatter": 1.0, "bisection": 2.0},
        "backend": "scatter",
    }
    try:
        plan = _fake_plan(key, "scatter")
        assert planner.record_observed(plan, 3.0)
        assert planner.record_observed(plan, 5.0)
        entry = planner._WISDOM[key]
        cell = entry["observed"]["scatter"]
        assert cell["n"] == 2 and cell["s"] == pytest.approx(4.0)
        # observed mean outranks the race median in the effective table...
        eff = planner.effective_timings(entry)
        assert eff == {"scatter": pytest.approx(4.0), "bisection": 2.0}
        # ...so the pinned decision flips to what production actually saw
        assert entry["backend"] == "bisection"
    finally:
        planner.forget_wisdom()


def test_record_observed_no_ops():
    planner.forget_wisdom()
    try:
        # no wisdom_key (estimate-planner plan) -> False
        assert not planner.record_observed(types.SimpleNamespace(backend="x"), 1.0)
        key = ("k",)
        planner._WISDOM[key] = {"timings": {"scatter": 1.0}, "backend": "scatter"}
        plan = _fake_plan(key, "scatter")
        assert not planner.record_observed(plan, 0.0)
        assert not planner.record_observed(plan, float("nan"))
        assert not planner.record_observed(_fake_plan(("gone",), "scatter"), 1.0)
        assert "observed" not in planner._WISDOM[key]
    finally:
        planner.forget_wisdom()


def test_merge_wisdom_entry_unions_observed():
    a = {"timings": {"scatter": 1.0, "alltoall": 3.0}, "backend": "scatter",
         "count": 1, "observed": {"scatter": {"n": 1, "s": 9.0}}}
    b = {"timings": {"scatter": 2.0, "alltoall": 3.0}, "backend": "scatter",
         "count": 1, "observed": {"scatter": {"n": 3, "s": 1.0},
                                  "bad": "junk"}}
    merged = planner.merge_wisdom_entry(a, b)
    cell = merged["observed"]["scatter"]
    assert cell["n"] == 4 and cell["s"] == pytest.approx(3.0)
    assert "bad" not in merged["observed"]
    # argmin runs over the effective table: observed scatter mean (3.0)
    # equal to alltoall race (3.0) -> tie broken by sorted name order
    assert merged["backend"] == "alltoall"


# ---------------------------------------------------------------------------
# 8-device acceptance: traced executor + Plan.profile + HLO stability
# ---------------------------------------------------------------------------

_TRACED_CODE = r"""
import dataclasses, hashlib
import jax, jax.numpy as jnp
import numpy as np
from repro.core import plan_fft
from repro.core import schedule as sch
from repro.core.compat import make_mesh
from repro.obs import TraceRecorder

mesh = make_mesh((8,), ("x",))
plan = plan_fft((64, 64), mesh, backend="scatter")
built = plan.schedule(False)

h0 = hashlib.sha256(plan.lower().as_text().encode()).hexdigest()
res = plan.profile(reps=2, warmup=1, record=False)
h1 = hashlib.sha256(plan.lower().as_text().encode()).hexdigest()
assert h0 == h1, "profiling changed the untraced hot path's HLO"
print("PASS hlo-stable")

exchanges = [st for st in built.stages if isinstance(st, sch.Exchange)]
assert len(exchanges) >= 1
# exactly one Exchange span per schedule Exchange stage per timed rep
ex_spans = res.trace.exchange_spans()
assert len(ex_spans) == res.reps * len(exchanges), (len(ex_spans), len(exchanges))
print("PASS span-count")

rows = res.exchange_rows()
assert len(rows) == len(exchanges)
c_item = jnp.dtype(jnp.complex64).itemsize
total = sum(r.wire_bytes for r in rows)
want = sch.schedule_comm_bytes(built, c_item // 2, c_item)
assert total == want, (total, want)  # exact, not approx: same byte walk
print("PASS wire-bytes")

# one observed row per schedule stage: Twiddle rides its Exchange, the
# conj/scale epilogue is its own span
n_tw = sum(isinstance(st, sch.Twiddle) for st in built.stages)
n_extra = int(built.conj) + int(built.conj or built.scale is not None)
assert len(res.rows) == len(built.stages) - n_tw + n_extra, (
    len(res.rows), len(built.stages), n_tw, n_extra)
assert all(r.observed_s > 0 for r in res.rows)
assert all(r.predicted_s is not None for r in res.exchange_rows())
tbl = res.table()
assert "observed us" in tbl and "wire bytes" in tbl
print("PASS row-per-stage")

# traced and untraced executors agree numerically
rng = np.random.default_rng(0)
hx = (rng.standard_normal((64, 64)) + 1j * rng.standard_normal((64, 64))).astype("complex64")
x = jax.device_put(jnp.asarray(hx), plan.input_spec().sharding)
rec = TraceRecorder()
y_t = np.asarray(sch.run_schedule(x, built, mesh, trace=rec))
y_u = np.asarray(sch.run_schedule(x, built, mesh))
np.testing.assert_allclose(y_t, y_u, rtol=2e-4, atol=2e-4)
assert len(rec.exchange_spans()) == len(exchanges)
print("PASS traced-numerics")

# trace artifact is loadable Chrome JSON with the Exchange attributes
doc = res.trace.to_chrome_trace()
exev = [e for e in doc["traceEvents"] if e.get("cat") == "exchange"]
assert exev and all(
    e["args"]["backend"] == "scatter" and e["args"]["wire_bytes"] > 0
    and "role" in e["args"] and "n_chunks" in e["args"] for e in exev)
print("PASS chrome-args")
"""


def test_traced_executor_acceptance_8dev():
    out = run_subprocess(_TRACED_CODE, devices=8)
    for tag in ("hlo-stable", "span-count", "wire-bytes", "row-per-stage",
                "traced-numerics", "chrome-args"):
        assert f"PASS {tag}" in out, out


_MEASURED_CODE = r"""
from repro.core import plan_fft, planner
from repro.core.comm_model import CommParams
from repro.core.compat import make_mesh

mesh = make_mesh((8,), ("x",))
plan = plan_fft((32, 32), mesh, planner="measure")
assert plan.wisdom_key is not None
res = plan.profile(reps=1, warmup=1)  # record=True folds into wisdom
entry = dict(planner.wisdom_items())[plan.wisdom_key]
obs = entry.get("observed", {})
assert plan.backend in obs and obs[plan.backend]["n"] == 1
eff = planner.effective_timings(entry)
assert eff[plan.backend] == obs[plan.backend]["s"]
print("PASS observed-channel")

fits = CommParams().refine_online(res.trace)
assert ("*", "*") in fits and all(
    isinstance(v, CommParams) for v in fits.values())
print("PASS refine-online")
"""


@pytest.mark.slow
def test_profile_feeds_wisdom_observed_8dev():
    out = run_subprocess(_MEASURED_CODE, devices=8)
    assert "PASS observed-channel" in out and "PASS refine-online" in out, out


_GLOBAL_CODE = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro.core import plan_fft
from repro.core.compat import make_mesh

mesh = make_mesh((8,), ("x",))
plan = plan_fft((32, 32), mesh, backend="xla_auto")
res = plan.profile(reps=1, warmup=1, record=False)
(row,) = res.rows
assert row.stage.startswith("global:") and row.kind == "Global"
assert row.observed_s > 0
print("PASS global-span")
"""


def test_global_backend_traces_one_span_8dev():
    out = run_subprocess(_GLOBAL_CODE, devices=8)
    assert "PASS global-span" in out, out
