"""Data pipeline: determinism, resumability, host sharding, prefetch."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import DataConfig, Prefetcher, SyntheticLM


def _ds(**kw):
    cfg = DataConfig(vocab_size=256, seq_len=32, global_batch=8, **kw)
    return SyntheticLM(cfg)


def test_batch_is_pure_function_of_step():
    ds = _ds()
    a = ds.batch_at(7)
    b = ds.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_next_tokens():
    ds = _ds(noise=0.0)
    b = ds.batch_at(0)
    # with zero noise, sequence is affine: labels = roll of tokens
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_disjoint():
    cfg = DataConfig(vocab_size=64, seq_len=16, global_batch=8)
    d0 = SyntheticLM(cfg, process_index=0, process_count=2)
    d1 = SyntheticLM(cfg, process_index=1, process_count=2)
    b0, b1 = d0.batch_at(3), d1.batch_at(3)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_prefetcher_order_and_resume():
    ds = _ds()
    pf = Prefetcher(ds, start_step=5, depth=2)
    try:
        s1, b1 = pf.next()
        s2, b2 = pf.next()
        assert (s1, s2) == (5, 6)
        np.testing.assert_array_equal(b1["tokens"], ds.batch_at(5)["tokens"])
    finally:
        pf.stop()


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 10_000), seed=st.integers(0, 1000))
def test_tokens_in_vocab(step, seed):
    ds = SyntheticLM(DataConfig(vocab_size=100, seq_len=8, global_batch=2, seed=seed))
    b = ds.batch_at(step)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 100
    assert b["tokens"].dtype == np.int32


def test_learnable_structure():
    """Low-noise stream must be predictable: next token correlates with
    an affine continuation (sanity for the e2e loss-decrease test)."""
    ds = _ds(noise=0.0)
    b = ds.batch_at(0)
    t = b["tokens"].astype(np.int64)
    stride = (t[:, 1] - t[:, 0]) % 256
    pred = (t[:, 1:] + stride[:, None]) % 256
    acc = (pred[:, :-1] == t[:, 2:]).mean()
    assert acc > 0.99
