"""Elastic scaling + compressed-DP: the fault-tolerance claims that need
multiple devices to mean anything (subprocess, 8 host devices)."""

import pytest

from conftest import run_subprocess

CROSS_MESH_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.compat import make_mesh
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
import tempfile, os

tmp = tempfile.mkdtemp()
mgr = CheckpointManager(tmp, keep=2)

# save on a (2,4) mesh with FSDP x TP sharding
mesh_a = make_mesh((2, 4), ("data", "model"))
w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
tree = {"w": jax.device_put(w, NamedSharding(mesh_a, P("data", "model"))),
        "step": jnp.asarray(7)}
mgr.save(10, tree, blocking=True)

# restore on a DIFFERENT mesh shape (4,2) -- elastic re-scale
mesh_b = make_mesh((4, 2), ("data", "model"))
shardings = {"w": NamedSharding(mesh_b, P("data", "model")),
             "step": NamedSharding(mesh_b, P())}
restored = mgr.restore(10, tree, shardings=shardings)
assert np.array_equal(np.asarray(restored["w"]), np.asarray(w))
assert restored["w"].sharding.mesh.shape["data"] == 4
print("PASS cross-mesh restore")

# restore on fewer devices entirely (half the fleet died)
mesh_c = make_mesh((2, 2), ("data", "model"))  # first 4 devices
sh_c = {"w": NamedSharding(mesh_c, P("data", "model")), "step": NamedSharding(mesh_c, P())}
restored_c = mgr.restore(10, tree, shardings=sh_c)
assert np.array_equal(np.asarray(restored_c["w"]), np.asarray(w))
print("PASS shrunk-fleet restore")
"""

DDP_COMPRESSED_CODE = r"""
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.core.compat import make_mesh
from repro.configs import TrainConfig, get_config
from repro.data import DataConfig, SyntheticLM
from repro.models import Model
from repro.train import init_ddp_state, make_ddp_compressed_step

mesh = make_mesh((4,), ("data",))
cfg = dataclasses.replace(get_config("phi3-medium-14b", reduced=True), dtype="float32")
model = Model(cfg)
ds = SyntheticLM(DataConfig(cfg.vocab_size, 16, 8, seed=0))

losses = {}
for comp in ("none", "int8"):
    tcfg = TrainConfig(learning_rate=2e-3, warmup_steps=2, total_steps=12,
                       grad_compression=comp)
    state = init_ddp_state(model, jax.random.PRNGKey(0), tcfg)
    step = make_ddp_compressed_step(model, tcfg, mesh)
    ls = []
    for s in range(12):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
        state, m = step(state, batch)
        ls.append(float(m["loss"]))
    losses[comp] = ls
    assert np.isfinite(ls).all()
    assert np.mean(ls[-3:]) < np.mean(ls[:3]), (comp, ls)

# int8 error-feedback must track the uncompressed trajectory closely
drift = max(abs(a - b) for a, b in zip(losses["none"], losses["int8"]))
assert drift < 0.15 * losses["none"][0], drift
print("PASS ddp int8 compression tracks fp32, drift", round(drift, 4))
"""


@pytest.mark.slow
def test_cross_mesh_checkpoint_restore():
    out = run_subprocess(CROSS_MESH_CODE, devices=8)
    assert out.count("PASS") == 2, out


@pytest.mark.slow
def test_ddp_compressed_training():
    out = run_subprocess(DDP_COMPRESSED_CODE, devices=4, timeout=900)
    assert "PASS" in out
