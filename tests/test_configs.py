"""Config registry: completeness, exact assigned numbers, param counts."""

import pytest

from repro.configs import ARCHS, SHAPES, apply_overrides, get_config
from repro.models.model import build_groups

EXPECTED = {
    # arch -> (L, d_model, H, kv, d_ff, vocab)
    "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
    "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
    "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
    "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
    "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
    "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
    "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
}

#: loose total-param sanity bands (analytic count vs the model's name)
PARAM_BANDS = {
    "phi-3-vision-4.2b": (3e9, 6e9),
    "mixtral-8x22b": (110e9, 180e9),
    "deepseek-v3-671b": (550e9, 800e9),
    "qwen2.5-32b": (25e9, 40e9),
    "gemma2-9b": (7e9, 13e9),
    "nemotron-4-15b": (12e9, 20e9),
    "phi3-medium-14b": (11e9, 18e9),
    "xlstm-1.3b": (0.8e9, 2.2e9),
    "hymba-1.5b": (0.9e9, 2.4e9),
    "whisper-medium": (0.25e9, 1.2e9),
}


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    assert set(ARCHS) == set(EXPECTED)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_exact_assigned_numbers(arch):
    cfg = ARCHS[arch]
    L, d, h, kv, ff, v = EXPECTED[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


@pytest.mark.parametrize("arch", sorted(PARAM_BANDS))
def test_param_count_band(arch):
    lo, hi = PARAM_BANDS[arch]
    n = ARCHS[arch].param_count()
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"


def test_deepseek_active_params():
    cfg = ARCHS["deepseek-v3-671b"]
    active = cfg.active_param_count()
    assert 25e9 <= active <= 60e9  # ~37B active in the paper


def test_reduced_configs_share_family():
    for arch in ARCHS:
        full, red = get_config(arch), get_config(arch, reduced=True)
        assert full.family == red.family
        assert (full.moe is None) == (red.moe is None)
        assert (full.mla is None) == (red.mla is None)
        assert red.d_model <= 128


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768 and SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288 and SHAPES["long_500k"].global_batch == 1


def test_overrides():
    cfg = apply_overrides(ARCHS["qwen2.5-32b"], {"num_layers": "2", "dtype": "float32"})
    assert cfg.num_layers == 2 and cfg.dtype == "float32"
    with pytest.raises(KeyError):
        apply_overrides(cfg, {"nonsense": "1"})


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_groups_cover_all_layers(arch):
    cfg = ARCHS[arch]
    groups = build_groups(cfg)
    per_layer = {"xlstm_pair": 2}
    total = sum(g.count * per_layer.get(g.kind, 1) for g in groups if g.kind != "enc")
    assert total == cfg.num_layers
    if cfg.is_encdec:
        enc = sum(g.count for g in groups if g.kind == "enc")
        assert enc == cfg.encoder_layers


def test_long_context_flags():
    assert ARCHS["xlstm-1.3b"].supports_long_context
    assert ARCHS["hymba-1.5b"].supports_long_context
    assert not ARCHS["qwen2.5-32b"].supports_long_context
    assert not ARCHS["gemma2-9b"].supports_long_context  # global layers are full attn
