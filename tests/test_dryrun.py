"""Dry-run machinery on reduced configs (subprocess: needs 512 host
devices + the production meshes). Full-size cells run via
``python -m repro.launch.dryrun --all`` (EXPERIMENTS.md §Dry-run)."""

import pytest

from conftest import run_subprocess

CODE = r"""
import sys
sys.argv = ["dryrun"]
from repro.launch import dryrun

for arch, shape in [
    ("qwen2.5-32b", "train_4k"),
    ("deepseek-v3-671b", "decode_32k"),
    ("xlstm-1.3b", "long_500k"),
]:
    for mesh in (["single", "multi"] if arch == "qwen2.5-32b" else ["multi"]):
        res = dryrun.run_cell(arch, shape, mesh, reduced=True)
        assert res["memory"]["peak_device_bytes"] > 0
        r = res["roofline"]
        assert r["flops"] > 0 and r["bottleneck"] in ("compute", "memory", "collective")
        print(f"PASS {arch} {shape} {mesh}")
"""


@pytest.mark.slow
def test_dryrun_reduced_cells():
    out = run_subprocess(CODE, devices=512, timeout=1200)
    assert out.count("PASS") == 4, out
