"""Recurrent mixers: chunkwise-parallel forms vs sequential decode steps."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import ssm


def test_mlstm_chunkwise_matches_decode_steps(rng):
    b, h, s, dk, dv = 2, 3, 24, 8, 8
    q = jnp.asarray(rng.standard_normal((b, h, s, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, dv)), jnp.float32)
    ip = jnp.asarray(rng.standard_normal((b, h, s)), jnp.float32)
    fp = jnp.asarray(rng.standard_normal((b, h, s)) + 2.0, jnp.float32)

    out_c, final_c = ssm.mlstm_chunkwise(q, k, v, ip, fp, chunk=8)

    st = ssm.init_mlstm_state(b, h, dk, dv)
    outs = []
    for t in range(s):
        o, st = ssm.mlstm_decode_step(q[:, :, t], k[:, :, t], v[:, :, t], ip[:, :, t], fp[:, :, t], st)
        outs.append(o)
    out_seq = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final_c.c), np.asarray(st.c), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final_c.m), np.asarray(st.m), rtol=2e-4, atol=2e-4)


def test_mlstm_chunk_invariance(rng):
    b, h, s, d = 1, 2, 32, 4
    args = [jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32) for _ in range(3)]
    gates = [jnp.asarray(rng.standard_normal((b, h, s)), jnp.float32) for _ in range(2)]
    o1, _ = ssm.mlstm_chunkwise(*args, *gates, chunk=4)
    o2, _ = ssm.mlstm_chunkwise(*args, *gates, chunk=16)
    o3, _ = ssm.mlstm_chunkwise(*args, *gates, chunk=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o3), rtol=2e-4, atol=2e-4)


def test_mlstm_padding(rng):
    """Non-multiple sequence lengths pad with identity gate steps."""
    b, h, d = 1, 2, 4
    for s in (7, 17, 23):
        args = [jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32) for _ in range(3)]
        gates = [jnp.asarray(rng.standard_normal((b, h, s)), jnp.float32) for _ in range(2)]
        o_pad, st_pad = ssm.mlstm_chunkwise(*args, *gates, chunk=8)
        o_ref, st_ref = ssm.mlstm_chunkwise(*args, *gates, chunk=s)  # single chunk
        np.testing.assert_allclose(np.asarray(o_pad), np.asarray(o_ref), rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(st_pad.c), np.asarray(st_ref.c), rtol=3e-4, atol=3e-4)


def test_mamba_full_matches_decode_steps(rng):
    import dataclasses

    cfg = get_config("hymba-1.5b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    p, _ = ssm.init_mamba(jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    di = int(cfg.ssm.expand * cfg.d_model)
    st = ssm.init_mamba_state(b, di, cfg.ssm.state_dim, cfg.ssm.conv_dim)
    full, final = ssm.apply_mamba(p, x, cfg, st)
    st2 = ssm.init_mamba_state(b, di, cfg.ssm.state_dim, cfg.ssm.conv_dim)
    outs = []
    for t in range(s):
        o, st2 = ssm.decode_mamba(p, x[:, t : t + 1], cfg, st2)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq), rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final.h), np.asarray(st2.h), rtol=2e-3, atol=2e-4)


def test_slstm_full_matches_decode_steps(rng):
    import dataclasses

    cfg = get_config("xlstm-1.3b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    p, _ = ssm.init_slstm_block(jax.random.PRNGKey(0), cfg)
    b, s = 2, 10
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    st = ssm.init_slstm_state(b, cfg.d_model)
    full, final = ssm.apply_slstm_block(p, x, cfg, st)
    st2 = ssm.init_slstm_state(b, cfg.d_model)
    outs = []
    for t in range(s):
        o, st2 = ssm.decode_slstm_block(p, x[:, t : t + 1], cfg, st2)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final.c), np.asarray(st2.c), rtol=2e-4, atol=2e-4)


def test_mlstm_block_stateful_matches_stateless(rng):
    import dataclasses

    cfg = get_config("xlstm-1.3b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    p, _ = ssm.init_mlstm_block(jax.random.PRNGKey(0), cfg)
    b, s = 1, 16
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    out_stateless, _ = ssm.apply_mlstm_block(p, x, cfg, None)
    di = int(cfg.ssm.expand * cfg.d_model)
    dh = di // cfg.num_heads
    st = ssm.MLSTMBlockState(
        cell=ssm.init_mlstm_state(b, cfg.num_heads, dh, dh),
        conv=jnp.zeros((b, 3, di), jnp.float32),
    )
    out_stateful, _ = ssm.apply_mlstm_block(p, x, cfg, st)
    np.testing.assert_allclose(
        np.asarray(out_stateless), np.asarray(out_stateful), rtol=1e-5, atol=1e-6
    )


def test_causal_conv_stateful(rng):
    x = jnp.asarray(rng.standard_normal((1, 12, 6)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 6)), jnp.float32)
    full, _ = ssm._causal_conv(x, w)
    # streaming: feed one step at a time
    state = jnp.zeros((1, 3, 6))
    outs = []
    for t in range(12):
        o, state = ssm._causal_conv(x[:, t : t + 1], w, state)
        outs.append(o)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stream), rtol=1e-5, atol=1e-6)
