"""Deprecation-shim coverage for the legacy plan surface (PR 1 kept
``FFTConfig``/``FFTPlan``/``make_plan`` as one-release shims; until now
nothing pinned their behavior, so a refactor could silently break the
delegation or drop the warning).

Asserts: ``make_plan`` emits exactly one DeprecationWarning attributed
to the *caller* (stacklevel=2), and the shim objects delegate every
method to a plan_fft-equivalent Plan.
"""

import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import FFTConfig, FFTPlan, make_plan, plan_fft  # noqa: E402
from repro.core.compat import make_mesh  # noqa: E402


def _mesh():
    return make_mesh((1,), ("model",))


def test_make_plan_emits_exactly_one_deprecation_warning_at_caller():
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        shim = make_plan((8, 8), _mesh(), strategy="alltoall")
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1, [str(w.message) for w in rec]
    assert "plan_fft" in str(dep[0].message)
    # stacklevel=2: the warning must point at THIS file (the caller),
    # not at repro/core/plan.py -- that is what makes the deprecation
    # actionable for downstream users
    assert dep[0].filename == __file__, dep[0].filename
    assert isinstance(shim, FFTPlan)


def test_make_plan_delegates_execution_and_layout():
    mesh = _mesh()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        shim = make_plan(
            (8, 8), mesh, strategy="alltoall", ndim_transform=2, transpose_back=True
        )
    ref = plan_fft((8, 8), mesh, backend="alltoall", transpose_back=True)

    rng = np.random.default_rng(0)
    x = jnp.asarray(
        (rng.standard_normal((8, 8)) + 1j * rng.standard_normal((8, 8))).astype(
            np.complex64
        )
    )
    np.testing.assert_allclose(
        np.asarray(shim.execute(x)), np.asarray(ref.execute(x)), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(shim.inverse(shim.execute(x))), np.asarray(x), rtol=1e-4, atol=1e-4
    )
    assert shim.comm_bytes() == ref.comm_bytes()
    assert shim.comm_bytes(jnp.complex128) == ref.comm_bytes(jnp.complex128)
    spec_shim, spec_ref = shim.input_spec(), ref.input_spec()
    assert spec_shim.shape == spec_ref.shape and spec_shim.dtype == spec_ref.dtype
    assert shim.input_sharding().spec == ref.input_sharding().spec
    assert shim.lower() is not None  # dry-run path stays wired


def test_fftconfig_carrier_fields_flow_through():
    """FFTConfig is the legacy field carrier: its strategy/ndim knobs
    must keep steering the underlying Plan."""
    cfg = FFTConfig(strategy="bisection", transpose_back=False)
    shim = FFTPlan(global_shape=(4, 8), mesh=_mesh(), axis_name="model", cfg=cfg)
    plan = shim._plan
    assert plan.backend == "bisection"
    assert plan.transpose_back is False and plan.ndim == 2
    shim3 = FFTPlan(
        global_shape=(4, 4, 4), mesh=_mesh(), axis_name="model",
        cfg=FFTConfig(strategy="scatter"), ndim_transform=3,
    )
    assert shim3._plan.ndim == 3 and shim3._plan.backend == "scatter"


def test_make_plan_warns_every_call_not_once():
    """`warnings.warn` with default filters can dedupe by location; the
    shim must rely on DeprecationWarning semantics, not on being called
    once -- guard that two calls under 'always' yield two warnings."""
    mesh = _mesh()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        make_plan((8, 8), mesh)
        make_plan((8, 8), mesh)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 2
