"""Serve engine: slot batching, ragged prompts, greedy determinism."""

import numpy as np
import pytest
import jax

from repro.configs import ServeConfig, get_config
from repro.models import Model
from repro.serve import ServeEngine


@pytest.fixture(scope="module")
def setup():
    import dataclasses

    cfg = get_config("qwen2.5-32b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = Model(cfg, attn_impl="chunked")
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_single_request(setup):
    cfg, model, params = setup
    eng = ServeEngine(model, params, ServeConfig(max_batch=2, max_seq=64))
    prompt = np.arange(5, dtype=np.int32) % cfg.vocab_size
    res = eng.run([prompt], max_new=6)
    assert len(res) == 1
    (tokens,) = res.values()
    assert len(tokens) == 6
    assert all(0 <= t < cfg.vocab_size for t in tokens)


def test_batched_matches_single(setup):
    """A request decoded alongside others must equal its solo decode
    (slot isolation: per-row cache lengths)."""
    cfg, model, params = setup
    pa = (np.arange(7) * 3 % cfg.vocab_size).astype(np.int32)
    pb = (np.arange(4) * 5 % cfg.vocab_size).astype(np.int32)

    solo = ServeEngine(model, params, ServeConfig(max_batch=2, max_seq=64)).run([pa], max_new=5)
    both_eng = ServeEngine(model, params, ServeConfig(max_batch=2, max_seq=64))
    both = both_eng.run([pa, pb], max_new=5)
    solo_tokens = list(solo.values())[0]
    assert both[0] == solo_tokens


def test_more_requests_than_slots(setup):
    cfg, model, params = setup
    eng = ServeEngine(model, params, ServeConfig(max_batch=2, max_seq=64))
    prompts = [(np.arange(3 + i) % cfg.vocab_size).astype(np.int32) for i in range(5)]
    res = eng.run(prompts, max_new=4)
    assert len(res) == 5
    assert all(len(v) == 4 for v in res.values())


def test_greedy_deterministic(setup):
    cfg, model, params = setup
    p = (np.arange(6) % cfg.vocab_size).astype(np.int32)
    r1 = ServeEngine(model, params, ServeConfig(max_batch=1, max_seq=64)).run([p], max_new=5)
    r2 = ServeEngine(model, params, ServeConfig(max_batch=1, max_seq=64)).run([p], max_new=5)
    assert list(r1.values()) == list(r2.values())
