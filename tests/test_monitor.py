"""Telemetry unit tests: nearest-rank percentiles, the LatencyWindow
rolling buffer, and StepMonitor's straggler-EMA edge cases (warmup
boundary, outliers never poisoning the baseline)."""

import math

import pytest

from repro.runtime.monitor import LatencyWindow, StepMonitor, percentiles


# ------------------------------------------------------------ percentiles
class TestPercentiles:
    def test_nearest_rank_basic(self):
        # 1..100: nearest-rank pQ of n=100 is exactly the Qth value
        data = list(range(1, 101))
        out = percentiles(data, qs=(50, 90, 99))
        assert out == {"p50": 50.0, "p90": 90.0, "p99": 99.0}

    def test_small_sample(self):
        # n=4: rank(50) = ceil(2) = 2, rank(99) = ceil(3.96) = 4
        out = percentiles([10.0, 20.0, 30.0, 40.0], qs=(50, 99))
        assert out["p50"] == 20.0
        assert out["p99"] == 40.0

    def test_single_sample_all_quantiles(self):
        out = percentiles([7.0], qs=(0, 50, 100))
        assert out == {"p0": 7.0, "p50": 7.0, "p100": 7.0}

    def test_unsorted_input(self):
        assert percentiles([3.0, 1.0, 2.0], qs=(100,))["p100"] == 3.0

    def test_empty_returns_zero(self):
        assert percentiles([], qs=(50, 99)) == {"p50": 0.0, "p99": 0.0}

    def test_fractional_quantile_label(self):
        out = percentiles(list(range(1, 1001)), qs=(99.9,))
        assert list(out) == ["p99_9"]
        assert out["p99_9"] == math.ceil(99.9 / 100 * 1000)

    def test_out_of_range_raises(self):
        with pytest.raises(ValueError):
            percentiles([1.0], qs=(101,))
        with pytest.raises(ValueError):
            percentiles([1.0], qs=(-1,))

    def test_boundary_quantiles_multi_sample(self):
        # documented convention: q=0 -> minimum, q=100 -> maximum
        out = percentiles([5.0, 1.0, 3.0], qs=(0, 100))
        assert out == {"p0": 1.0, "p100": 5.0}

    def test_distinct_quantiles_with_colliding_labels_raise(self):
        # 99.9 and 99.9000001 are different floats but both format to
        # "p99_9" at %g precision: two different quantiles silently
        # sharing one dict key would drop a result, so this must raise
        with pytest.raises(ValueError, match="collide"):
            percentiles([1.0, 2.0], qs=(99.9, 99.9000001))

    def test_same_quantile_twice_is_not_a_collision(self):
        out = percentiles([1.0, 2.0], qs=(50, 50.0))
        assert out == {"p50": 1.0}


# ---------------------------------------------------------- LatencyWindow
class TestLatencyWindow:
    def test_rolling_trim_keeps_recent(self):
        w = LatencyWindow(maxlen=4)
        for v in [100.0, 100.0, 1.0, 2.0, 3.0, 4.0]:
            w.record(v)
        # the two 100s fell off the window: percentiles see only 1..4
        assert len(w) == 4
        assert w.percentiles(qs=(100,))["p100"] == 4.0
        # but count/total are lifetime
        assert w.count == 6
        assert w.summary()["count"] == 6
        assert w.summary()["mean"] == pytest.approx(210.0 / 6)

    def test_summary_fields(self):
        w = LatencyWindow()
        s = w.summary()
        assert s["count"] == 0 and s["mean"] == 0.0 and s["max"] == 0.0
        w.record(2.0)
        w.record(4.0)
        s = w.summary(qs=(50,))
        assert s["p50"] == 2.0 and s["max"] == 4.0 and s["mean"] == 3.0


# ------------------------------------------------------------ StepMonitor
def _feed(mon, seconds):
    """Drive StepMonitor with synthetic durations via a patched clock."""
    t = [0.0]
    real = __import__("time").perf_counter
    try:
        for dt in seconds:
            mon._t0 = t[0]
            t[0] += dt
            import repro.runtime.monitor as m

            orig = m.time.perf_counter
            m.time.perf_counter = lambda: t[0]
            try:
                yield mon.stop()
            finally:
                m.time.perf_counter = orig
    finally:
        assert __import__("time").perf_counter is real


class TestStepMonitorEMA:
    def test_no_flag_during_warmup(self):
        mon = StepMonitor(warmup=3, straggler_factor=2.0)
        # a huge step inside warmup must not be flagged
        stats = list(_feed(mon, [1.0, 50.0, 1.0]))
        assert [s.flagged for s in stats] == [False, False, False]

    def test_flag_after_warmup_and_baseline_survives(self):
        mon = StepMonitor(ema_alpha=0.5, warmup=3, straggler_factor=2.0)
        stats = list(_feed(mon, [1.0, 1.0, 1.0, 10.0, 1.0]))
        assert stats[3].flagged  # 10s > 2 * ~1s EMA
        # the outlier did NOT update the EMA: baseline stays ~1s, so a
        # normal step right after is not flagged against a poisoned mean
        assert mon.ema == pytest.approx(1.0)
        assert not stats[4].flagged

    def test_warmup_boundary_exact(self):
        # warmup=2: first flag-eligible step is the third (index 2)
        mon = StepMonitor(ema_alpha=0.0, warmup=2, straggler_factor=2.0)
        stats = list(_feed(mon, [1.0, 10.0, 10.0]))
        assert not stats[1].flagged  # len(history)==1 < warmup
        assert stats[2].flagged  # len(history)==2 >= warmup

    def test_unflagged_steps_update_ema(self):
        mon = StepMonitor(ema_alpha=1.0, warmup=100)
        list(_feed(mon, [1.0, 3.0]))
        assert mon.ema == pytest.approx(3.0)  # alpha=1 -> tracks last

    def test_percentiles_over_history_and_window(self):
        mon = StepMonitor(warmup=1000)
        list(_feed(mon, [float(i) for i in range(1, 11)]))
        assert mon.percentiles(qs=(50,))["p50"] == 5.0
        assert mon.percentiles(qs=(50,), window=2)["p50"] == 9.0

    def test_straggler_report_counts(self):
        mon = StepMonitor(ema_alpha=0.5, warmup=1, straggler_factor=2.0)
        list(_feed(mon, [1.0, 1.0, 8.0, 1.0]))
        rep = mon.straggler_report()
        assert rep["steps"] == 4
        assert rep["flagged"] == 1
        assert rep["worst"] == 8.0


def _step(mon, dt, **stop_kwargs):
    """One synthetic step of duration dt through a patched clock."""
    import repro.runtime.monitor as m

    orig = m.time.perf_counter
    mon._t0 = 0.0
    m.time.perf_counter = lambda: dt
    try:
        return mon.stop(**stop_kwargs)
    finally:
        m.time.perf_counter = orig


class TestStepMonitorTelemetry:
    def test_culprit_names_slowest_span(self):
        mon = StepMonitor(warmup=0)
        st = _step(mon, 1.0, spans=[("input", 0.1), ("step_fn", 0.9)])
        assert st.culprit == "step_fn"
        # trace-span objects and JSONL dicts parse the same way
        span_obj = type("S", (), {"name": "exchange", "dur": 2.0})()
        st = _step(mon, 2.5, spans=[span_obj, {"name": "fft", "dur": 0.5}])
        assert st.culprit == "exchange"
        # no spans / unusable spans -> no attribution, no crash
        assert _step(mon, 1.0).culprit is None
        assert _step(mon, 1.0, spans=[{"dur": 1.0}, ("x",)]).culprit is None

    def test_straggler_report_attributes_culprits(self):
        mon = StepMonitor(ema_alpha=0.0, warmup=1, straggler_factor=2.0)
        _step(mon, 1.0, spans=[("input", 1.0)])
        _step(mon, 1.0, spans=[("input", 1.0)])
        _step(mon, 9.0, spans=[("input", 0.5), ("step_fn", 8.5)])
        _step(mon, 9.0, spans=[("input", 8.0), ("step_fn", 1.0)])
        rep = mon.straggler_report()
        assert rep["flagged"] == 2
        assert rep["culprits"] == {"step_fn": 1, "input": 1}

    def test_history_window_bounded_counters_lifetime(self):
        mon = StepMonitor(warmup=10**9, history_limit=4)
        for i in range(10):
            _step(mon, float(i + 1), tokens=100)
        assert len(mon.history) == 4  # always-on recording stays bounded
        assert mon.straggler_report()["steps"] == 10  # lifetime survives trim
        assert mon.percentiles(qs=(0,))["p0"] == 7.0  # oldest retained step

    def test_reset_drops_everything(self):
        mon = StepMonitor(ema_alpha=0.0, warmup=1, straggler_factor=2.0)
        _step(mon, 1.0)
        _step(mon, 1.0)
        _step(mon, 9.0)
        assert mon.flag_count == 1 and mon.ema is not None
        mon.reset()
        assert mon.ema is None and len(mon.history) == 0
        assert mon.flag_count == 0
        rep = mon.straggler_report()
        assert rep == {
            "steps": 0, "flagged": 0, "ema_s": None, "worst": 0.0, "culprits": {},
        }
        # post-reset, a big step inside the fresh warmup is not flagged
        assert not _step(mon, 50.0).flagged
