"""Measured planner (FFTW_MEASURE analogue): backend selection by
injected timings, wisdom round-trip, alpha-beta calibration fit, and the
plan-level fixes that ride along (lower() executable reuse,
chunk_compute_s threading)."""

import json

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import CommParams, backends, plan_fft, planner
from repro.core.compat import make_mesh_1d


@pytest.fixture(autouse=True)
def _fresh_wisdom():
    planner.forget_wisdom()
    yield
    planner.forget_wisdom()


def _fake_timer(table, calls=None):
    def timer(plan):
        if calls is not None:
            calls.append(plan.backend)
        return table[plan.backend]

    return timer


def _supported(p):
    return [n for n in backends.available() if backends.get(n).supports(p)]


def test_measure_picks_argmin_of_injected_timings():
    mesh = make_mesh_1d(1)
    names = _supported(1)
    table = {n: float(i + 2) for i, n in enumerate(names)}
    table["bisection"] = 0.5  # the planted winner
    plan = plan_fft((32, 32), mesh, planner="measure", timer=_fake_timer(table))
    assert plan.backend == "bisection"
    assert plan.planner == "measure"
    assert plan.measured == table
    assert not plan.wisdom_hit
    # every supported backend was timed
    assert set(plan.measured) == set(names)


def test_measure_tie_breaks_deterministically():
    mesh = make_mesh_1d(1)
    table = {n: 1.0 for n in _supported(1)}
    plan = plan_fft((32, 32), mesh, planner="measure", timer=_fake_timer(table))
    assert plan.backend == sorted(table)[0]


def test_second_identical_plan_hits_wisdom_without_remeasuring():
    mesh = make_mesh_1d(1)
    table = {n: float(i + 1) for i, n in enumerate(_supported(1))}
    calls = []
    timer = _fake_timer(table, calls)
    p1 = plan_fft((32, 32), mesh, planner="measure", timer=timer)
    n_measured = len(calls)
    assert n_measured == len(table)
    p2 = plan_fft((32, 32), mesh, planner="measure", timer=timer)
    assert len(calls) == n_measured  # no re-measurement
    assert p2.wisdom_hit and not p1.wisdom_hit
    assert p2.backend == p1.backend
    assert p2.measured == p1.measured
    # a *different* problem measures again
    plan_fft((64, 64), mesh, planner="measure", timer=timer)
    assert len(calls) == 2 * n_measured


def test_mutating_plan_measured_does_not_corrupt_wisdom():
    """Regression: the miss path stored the same dict object on the plan
    and in the wisdom store, so user mutation of the public timing table
    rewrote (and export_wisdom persisted) the cached entry."""
    mesh = make_mesh_1d(1)
    table = {n: float(i + 1) for i, n in enumerate(_supported(1))}
    p1 = plan_fft((32, 32), mesh, planner="measure", timer=_fake_timer(table))
    p1.measured.clear()  # e.g. a caller post-processing timings in place
    p2 = plan_fft((32, 32), mesh, planner="measure", timer=_fake_timer(table))
    assert p2.wisdom_hit and p2.measured == table
    assert json.loads(planner.export_wisdom())["entries"]


def test_use_wisdom_false_forces_remeasure():
    mesh = make_mesh_1d(1)
    table = {n: 1.0 for n in _supported(1)}
    calls = []
    timer = _fake_timer(table, calls)
    plan_fft((32, 32), mesh, planner="measure", timer=timer)
    plan_fft((32, 32), mesh, planner="measure", timer=timer, use_wisdom=False)
    assert len(calls) == 2 * len(table)


def test_pinned_backend_measure_times_only_that_backend():
    mesh = make_mesh_1d(1)
    calls = []
    plan = plan_fft(
        (32, 32),
        mesh,
        planner="measure",
        backend="scatter",
        timer=_fake_timer({"scatter": 1.0}, calls),
    )
    assert plan.backend == "scatter"
    assert calls == ["scatter"]


def test_wisdom_export_import_roundtrip(tmp_path):
    mesh = make_mesh_1d(1)
    table = {n: float(i + 1) for i, n in enumerate(_supported(1))}
    calls = []
    timer = _fake_timer(table, calls)
    p1 = plan_fft((32, 32), mesh, planner="measure", timer=timer)

    path = tmp_path / "wisdom.json"
    text = planner.export_wisdom(str(path))
    data = json.loads(path.read_text())
    assert data == json.loads(text)
    assert data["version"] == planner.WISDOM_VERSION
    assert len(data["entries"]) == 1
    (key,) = data["entries"]
    assert "shape=32x32" in key and "P=1" in key and "dtype=complex64" in key

    planner.forget_wisdom()
    assert planner.wisdom_size() == 0
    assert planner.import_wisdom(str(path)) == 1
    n_calls = len(calls)
    p2 = plan_fft((32, 32), mesh, planner="measure", timer=timer)
    assert len(calls) == n_calls  # imported wisdom, no re-measure
    assert p2.wisdom_hit and p2.backend == p1.backend


def test_forward_and_inverse_plans_measure_separately():
    """Regression: the wisdom key omitted the direction, so an inverse
    plan silently replayed forward-measured wisdom without ever timing
    the inverse transform."""
    mesh = make_mesh_1d(1)
    table = {n: 1.0 for n in _supported(1)}
    calls = []
    timer = _fake_timer(table, calls)
    plan_fft((32, 32), mesh, planner="measure", timer=timer)
    inv = plan_fft((32, 32), mesh, direction="inverse", planner="measure", timer=timer)
    assert not inv.wisdom_hit
    assert len(calls) == 2 * len(table)  # inverse measured on its own
    inv2 = plan_fft((32, 32), mesh, direction="inverse", planner="measure", timer=timer)
    assert inv2.wisdom_hit and len(calls) == 2 * len(table)


def test_plans_over_different_mesh_axes_measure_separately():
    """Regression: the wisdom key omitted the mesh axis, so a plan over
    a different axis of the same mesh replayed the other axis's winner
    (on hardware the axes can be entirely different fabrics)."""
    from repro.core.compat import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    table = {n: 1.0 for n in _supported(1)}
    calls = []
    timer = _fake_timer(table, calls)
    plan_fft((32, 32), mesh, axis_name="model", planner="measure", timer=timer)
    other = plan_fft((32, 32), mesh, axis_name="data", planner="measure", timer=timer)
    assert not other.wisdom_hit
    assert len(calls) == 2 * len(table)


def test_malformed_wisdom_entry_dropped_and_remeasured():
    """Wisdom is advisory: an entry without a usable backend (hand-edited
    or foreign file) must be dropped and re-measured, not KeyError."""
    mesh = make_mesh_1d(1)
    table = {n: 1.0 for n in _supported(1)}
    calls = []
    timer = _fake_timer(table, calls)
    good = plan_fft((32, 32), mesh, planner="measure", timer=timer)
    # corrupt the stored entry in place (simulates a bad wisdom file)
    (key,) = json.loads(planner.export_wisdom())["entries"]
    planner._WISDOM[key] = {}
    replanned = plan_fft((32, 32), mesh, planner="measure", timer=timer)
    assert not replanned.wisdom_hit and replanned.backend == good.backend
    assert len(calls) == 2 * len(table)  # re-measured
    # and the store healed itself
    assert planner._WISDOM[key]["backend"] == good.backend


def test_different_mesh_topologies_measure_separately():
    """Regression: the wisdom key omitted the mesh topology, so a winner
    measured on one mesh was replayed on a differently-shaped mesh with
    the same fft-axis size."""
    from repro.core.compat import make_mesh

    table = {n: 1.0 for n in _supported(1)}
    calls = []
    timer = _fake_timer(table, calls)
    plan_fft((32, 32), make_mesh((1,), ("model",)), planner="measure", timer=timer)
    other = plan_fft(
        (32, 32),
        make_mesh((1, 1), ("model", "data")),
        axis_name="model",
        planner="measure",
        timer=timer,
    )
    assert not other.wisdom_hit
    assert len(calls) == 2 * len(table)


def test_import_wisdom_tolerates_malformed_files():
    """Advisory contract: malformed wisdom merges 0 entries, never raises."""
    assert planner.import_wisdom("[1, 2]") == 0  # non-object JSON text
    assert planner.import_wisdom('{"version": 1, "entries": ["not", "a", "dict"]}') == 0
    assert planner.import_wisdom('{"no": "version"}') == 0
    assert planner.wisdom_size() == 0


def test_calibrate_constant_sweep_falls_back_on_beta():
    """A flat (latency-only) sweep cannot identify bandwidth: the fit
    must warn and keep the default beta rather than silently producing
    an absurd 1e26 B/s constant that zeroes every bandwidth term."""
    from repro.core import comm_model as cm

    with pytest.warns(RuntimeWarning, match="bandwidth not identifiable"):
        prm = CommParams.calibrate(timer=lambda m: 1e-4)
    assert prm.beta_bytes_s == cm.ICI_BW_PER_LINK * cm.ICI_LINKS
    assert abs(prm.alpha_s - 5e-5) < 1e-8  # intercept/2 still fitted


def test_import_wisdom_missing_file_raises_file_not_found(tmp_path):
    """Regression: a typo'd path fell through to json.loads(path) and
    raised a baffling JSONDecodeError instead of FileNotFoundError."""
    with pytest.raises(FileNotFoundError):
        planner.import_wisdom(str(tmp_path / "no_such_wisdom.json"))


def test_calibrate_defaults_to_fft_axis():
    """Regression: calibrate ping-ponged over the FIRST mesh axis while
    every plan ships over fft_axis(mesh) -- on a multi-axis mesh that
    fits the wrong fabric."""
    from repro.core import comm_model as cm
    from repro.core.compat import make_mesh
    from repro.core.sharding import fft_axis

    mesh = make_mesh((1, 1), ("data", "model"))
    assert fft_axis(mesh) == "model"
    timer = cm._pingpong_timer(mesh, None, warmup=0, iters=1)
    assert timer.axis_name == "model"  # not the first axis ("data")
    assert timer(4) >= 0.0  # and the roundtrip actually runs on that axis


def test_import_wisdom_accepts_json_text_and_rejects_other_versions():
    assert planner.import_wisdom('{"version": -1, "entries": {"k": {}}}') == 0
    assert planner.wisdom_size() == 0
    text = json.dumps(
        {"version": planner.WISDOM_VERSION, "entries": {"k": {"backend": "scatter"}}}
    )
    assert planner.import_wisdom(text) == 1
    assert planner.wisdom_size() == 1


def test_measure_real_timer_smoke():
    """Default (real-clock) path on one device: picks something it
    actually timed, and the timings are positive."""
    mesh = make_mesh_1d(1)
    plan = plan_fft((16, 16), mesh, planner="measure")
    assert plan.backend in plan.measured
    assert plan.measured[plan.backend] == min(plan.measured.values())
    assert all(t > 0 for t in plan.measured.values())


def test_invalid_planner_rejected():
    mesh = make_mesh_1d(1)
    with pytest.raises(ValueError, match="planner"):
        plan_fft((32, 32), mesh, planner="guess")
    # measure-only knobs with the (default) estimate planner: a forgotten
    # planner="measure" must fail loudly, not silently skip the timer
    with pytest.raises(ValueError, match="planner='measure'"):
        plan_fft((32, 32), mesh, timer=lambda plan: 1.0)
    with pytest.raises(ValueError, match="planner='measure'"):
        plan_fft((32, 32), mesh, use_wisdom=False)


def test_wisdom_entry_without_timings_remeasured():
    """A hit must come with the full timing table (Plan.measured's
    contract); an entry holding only a backend is advisory-dropped."""
    mesh = make_mesh_1d(1)
    table = {n: 1.0 for n in _supported(1)}
    calls = []
    timer = _fake_timer(table, calls)
    plan_fft((32, 32), mesh, planner="measure", timer=timer)
    (key,) = json.loads(planner.export_wisdom())["entries"]
    planner._WISDOM[key] = {"backend": sorted(table)[0]}  # no timings
    replanned = plan_fft((32, 32), mesh, planner="measure", timer=timer)
    assert not replanned.wisdom_hit
    assert replanned.measured == table
    assert len(calls) == 2 * len(table)


# ---------------------------------------------------------------------------
# CommParams.calibrate
# ---------------------------------------------------------------------------


def test_calibrate_recovers_alpha_beta_from_synthetic_timings():
    alpha, beta = 2.5e-6, 40e9
    prm = CommParams.calibrate(timer=lambda m: 2 * (alpha + m / beta))
    assert abs(prm.alpha_s - alpha) / alpha < 1e-6
    assert abs(prm.beta_bytes_s - beta) / beta < 1e-6


def test_calibrate_noisy_fit_close():
    alpha, beta = 1e-5, 10e9
    rng = np.random.default_rng(0)

    def timer(m):
        return 2 * (alpha + m / beta) * (1 + 0.01 * rng.standard_normal())

    prm = CommParams.calibrate(timer=timer)
    assert abs(prm.alpha_s - alpha) / alpha < 0.25
    assert abs(prm.beta_bytes_s - beta) / beta < 0.05


def test_calibrated_params_drive_estimate_selection():
    """estimate mode ranks with the calibrated constants, not the
    module-level v5e numbers: a fabric measured with ~1 s per-message
    latency must predict second-scale exchanges, and per-message cost
    must separate the many-message schedules from the single collective
    (the paper's Fig. 3 parcelport separation)."""
    mesh = make_mesh_1d(1)
    lat = CommParams.calibrate(timer=lambda m: 2 * (1.0 + m / 1e12))  # 1 s alpha
    assert abs(lat.alpha_s - 1.0) < 1e-6
    m_bytes, p = 8 * 2**20, 16
    # alpha-dominated fabric: cost ~ message count (1 vs log P vs P-1)
    costs = {n: backends.get(n).cost(m_bytes, p, lat) for n in backends.available()}
    assert costs["alltoall"] < costs["bisection"] < costs["scatter"]
    assert backends.cheapest(m_bytes, p, lat) == "alltoall"
    # the calibrated params flow into the plan's own ranking
    plan = plan_fft((64, 64), mesh, params=lat)
    assert plan.params is lat
    default = plan_fft((64, 64), mesh, backend=plan.backend).predict()
    for name, t in plan.predict().items():
        assert t >= default[name]  # v5e napkin constants are wildly optimistic here


def test_calibrate_validates_inputs():
    with pytest.raises(ValueError, match="2 message sizes"):
        CommParams.calibrate(timer=lambda m: m * 1e-9, sizes=(4096,))
    with pytest.raises(ValueError, match="mesh"):
        CommParams.calibrate()


def test_calibrate_real_pingpong_single_device():
    """The real measurement path runs (P=1 self-permute): constants come
    back finite and positive-ish even on a degenerate mesh."""
    mesh = make_mesh_1d(1)
    prm = CommParams.calibrate(mesh, sizes=(4096, 65536, 262144), iters=2)
    assert np.isfinite(prm.alpha_s) and prm.alpha_s >= 0
    assert np.isfinite(prm.beta_bytes_s) and prm.beta_bytes_s > 0


# ---------------------------------------------------------------------------
# Plan fixes riding along: lower() reuse + chunk_compute_s threading
# ---------------------------------------------------------------------------


def test_lower_reuses_cached_executable():
    """Regression: lower() built a fresh jax.jit wrapper, bypassing the
    cache and understating Plan.compiles."""
    mesh = make_mesh_1d(1)
    plan = plan_fft((16, 16), mesh, backend="alltoall")
    plan.lower()
    assert plan.compiles == 1
    x = jnp.zeros((16, 16), jnp.complex64)
    plan.execute(x)
    assert plan.compiles == 1  # same wrapper, not a second one
    plan.lower()
    assert plan.compiles == 1


def test_predict_threads_chunk_compute():
    """Heavy per-chunk compute must surface the streaming backends'
    overlap advantage in the plan-level ranking."""
    mesh = make_mesh_1d(1)
    plan = plan_fft((64, 64), mesh, backend="alltoall")
    base = plan.predict()
    heavy = plan.predict(chunk_compute_s=1e-3)
    assert heavy["alltoall"] > base["alltoall"]  # threaded through to cost()
    # plan-level default: chunk_compute_s set at plan time feeds predict()
    plan2 = plan_fft((64, 64), mesh, backend="alltoall", chunk_compute_s=1e-3)
    assert plan2.predict() == heavy
    # the ranking consequence (P>1 model; predict() uses this same path):
    # streaming scatter overlaps per-chunk compute, monolithic alltoall
    # serializes all P of them
    prm = plan.params
    assert backends.get("scatter").cost(2**20, 8, prm, 1e-3) < backends.get(
        "alltoall"
    ).cost(2**20, 8, prm, 1e-3)


MEASURE_4DEV_CODE = r"""
import numpy as np, jax.numpy as jnp
from repro.core import CommParams, plan_fft
from repro.core.compat import make_mesh

mesh = make_mesh((4,), ("model",))
plan = plan_fft((64, 64), mesh, planner="measure")
assert plan.backend in plan.measured
assert plan.measured[plan.backend] == min(plan.measured.values())
plan2 = plan_fft((64, 64), mesh, planner="measure")
assert plan2.wisdom_hit and plan2.backend == plan.backend, (plan2.wisdom_hit, plan2.backend)

rng = np.random.default_rng(0)
x = (rng.standard_normal((64, 64)) + 1j * rng.standard_normal((64, 64))).astype(np.complex64)
ref = np.fft.fft2(x)
y = np.asarray(plan.execute(jnp.asarray(x)))
assert np.abs(y - ref.T).max() < 1e-4 * np.abs(ref).max()
print("PASS measured plan 4dev")

prm = CommParams.calibrate(mesh, sizes=(4096, 65536, 262144), iters=3)
assert np.isfinite(prm.alpha_s) and np.isfinite(prm.beta_bytes_s) and prm.beta_bytes_s > 0
est = plan_fft((64, 64), mesh, params=prm)
assert est.predict()  # estimate ranking with fabric-measured constants
print("PASS calibrate 4dev")
"""


@pytest.mark.slow
def test_measure_planner_and_calibrate_4dev():
    """End-to-end on a real (host-device) mesh: measured selection,
    wisdom hit, numerical correctness of the picked plan, calibration."""
    from conftest import run_subprocess

    out = run_subprocess(MEASURE_4DEV_CODE, devices=4)
    assert out.count("PASS") == 2, out


# ------------------------------------------------- wisdom merge + atomic I/O
def test_merge_wisdom_entry_unions_timings_and_reargmins():
    old = {"backend": "scatter", "timings": {"scatter": 2.0, "bisection": 5.0}}
    new = {"backend": "pairwise", "timings": {"pairwise": 1.0, "scatter": 3.0}}
    merged = planner.merge_wisdom_entry(old, new)
    # union keeps candidates only one side timed; overlaps take the newer
    assert merged["timings"] == {"scatter": 3.0, "bisection": 5.0, "pairwise": 1.0}
    assert merged["backend"] == "pairwise"  # argmin of the combined table
    # malformed sides lose outright, never raise
    assert planner.merge_wisdom_entry(old, {"backend": "x"}) == old
    assert planner.merge_wisdom_entry("junk", new) == new
    assert planner.merge_wisdom_entry(None, {}) == {}


def test_export_wisdom_merges_existing_file(tmp_path):
    """Two processes exporting to the same wisdom path interleave their
    entries instead of the second clobbering the first."""
    mesh = make_mesh_1d(1)
    table = {n: float(i + 1) for i, n in enumerate(_supported(1))}
    path = tmp_path / "wisdom.json"

    plan_fft((32, 32), mesh, planner="measure", timer=_fake_timer(table))
    planner.export_wisdom(str(path))
    planner.forget_wisdom()
    plan_fft((64, 64), mesh, planner="measure", timer=_fake_timer(table))
    planner.export_wisdom(str(path))  # a different process's sweep

    data = json.loads(path.read_text())
    shapes = {k.split("|")[1] for k in data["entries"]}
    assert shapes == {"shape=32x32", "shape=64x64"}
    # merge=False writes exactly this process's store
    planner.export_wisdom(str(path), merge=False)
    assert len(json.loads(path.read_text())["entries"]) == 1


def test_export_wisdom_same_key_merge_prefers_in_memory(tmp_path):
    """Same-key conflict on export: the in-memory (newer) entry's
    overlapping timings win, disk-only candidates survive."""
    path = tmp_path / "wisdom.json"
    k = "v1|shape=8x8|ndim=2|dtype=complex64|P=1|backends=x|dev=cpu|mesh=m1"
    path.write_text(json.dumps({
        "version": planner.WISDOM_VERSION,
        "entries": {k: {"backend": "old", "timings": {"old": 0.1, "other": 9.0}}},
    }))
    planner._WISDOM[k] = {"backend": "new", "timings": {"new": 0.5, "old": 7.0}}
    data = json.loads(planner.export_wisdom(str(path)))
    assert data["entries"][k]["timings"] == {"old": 7.0, "other": 9.0, "new": 0.5}
    assert data["entries"][k]["backend"] == "new"


def test_export_wisdom_atomic_leaves_no_temp_files(tmp_path):
    path = tmp_path / "w.json"
    planner._WISDOM["k"] = {"backend": "b", "timings": {"b": 1.0}}
    planner.export_wisdom(str(path))
    planner.export_wisdom(str(path))  # replace an existing file
    assert [p.name for p in tmp_path.iterdir()] == ["w.json"]
    # corrupt existing files are overwritten, not fatal
    path.write_text("{broken")
    planner.export_wisdom(str(path))
    assert json.loads(path.read_text())["entries"]


def test_import_wisdom_merges_instead_of_overwriting():
    """Importing an older file can't undo newer in-process measurements
    of candidates the file never timed."""
    k = "v1|shape=8x8|ndim=2|dtype=complex64|P=1|backends=x|dev=cpu|mesh=m1"
    planner._WISDOM[k] = {"backend": "fast", "timings": {"fast": 0.1}}
    n = planner.import_wisdom(json.dumps({
        "version": planner.WISDOM_VERSION,
        "entries": {k: {"backend": "slow", "timings": {"slow": 5.0}}},
    }))
    assert n == 1
    assert planner._WISDOM[k]["backend"] == "fast"
    assert planner._WISDOM[k]["timings"] == {"fast": 0.1, "slow": 5.0}


def test_parse_wisdom_key_roundtrip():
    """Keys written by a real measure run decode back to the problem --
    the serving pool's warm start depends on this."""
    mesh = make_mesh_1d(1)
    table = {n: 1.0 for n in _supported(1)}
    plan_fft((2, 32, 32), mesh, planner="measure", timer=_fake_timer(table))
    (key,) = planner._WISDOM
    info = planner.parse_wisdom_key(key)
    assert info is not None
    assert info["shape"] == (2, 32, 32) and info["ndim"] == 2
    assert info["dtype"] == "complex64" and info["p"] == 1
    assert info["decomp"] == "slab" and info["direction"] == "forward"
    assert not info["real"] and not info["transpose_back"]
    # foreign keys decode to None, not exceptions
    assert planner.parse_wisdom_key("v999|shape=8x8") is None
    assert planner.parse_wisdom_key("garbage") is None
    assert planner.parse_wisdom_key("v1|shape=axb|ndim=2") is None
