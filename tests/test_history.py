"""Perf observatory: history ledger + noise-aware regression gates
(:mod:`repro.obs.history`, ``benchmarks/regress.py``), persisted
calibration (planner calibration store, wisdom round-trip), and planner
decision provenance (``Plan.why()`` / ``selection_channel``)."""

import json
import sys

import pytest

from conftest import REPO

if REPO not in sys.path:
    sys.path.insert(0, REPO)

from repro.core import CommParams, plan_fft, planner  # noqa: E402
from repro.core.compat import make_mesh_1d  # noqa: E402
from repro.obs import history as h  # noqa: E402


@pytest.fixture(autouse=True)
def _fresh_stores():
    planner.forget_wisdom()
    planner.forget_calibration()
    yield
    planner.forget_wisdom()
    planner.forget_calibration()


def _fake_timer(table):
    return lambda plan: table[plan.backend]


def _snap(metrics, commit="c0", ts="2026-01-01T00:00:00+00:00"):
    return {
        "schema": h.HISTORY_SCHEMA,
        "commit": commit,
        "device_kind": "cpu",
        "timestamp": ts,
        "sections": {},
        "metrics": dict(metrics),
    }


# ---------------------------------------------------------------------------
# Snapshot keys / ledger IO
# ---------------------------------------------------------------------------


def test_row_metrics_keys_are_stable_per_section():
    fft = {"bench": "fft2", "n": 256, "p": 8, "backend": "scatter@f4",
           "measured_us": 12.5}
    assert h.row_metrics(fft) == [("fft2|n256,p8,scatter@f4|measured_us", 12.5)]
    pencil = {"bench": "fft3_decomp", "n": 64, "p": 8, "decomp": "pencil",
              "grid": "4x2", "backend": "alltoall+scatter", "measured_us": 3.0}
    (key, _), = h.row_metrics(pencil)
    assert key == "fft3_decomp|n64,p8,pencil,4x2,alltoall+scatter|measured_us"
    serve = {"bench": "serve", "row": "load_sweep", "n": 128, "p": 8,
             "op": "fft2", "coalesce": True, "load": 16,
             "p50_us": 10.0, "p99_us": 20.0, "tps": 500.0}
    keys = dict(h.row_metrics(serve))
    assert set(keys) == {
        "serve|load_sweep,n128,p8,fft2,coalesce=1,load16|p50_us",
        "serve|load_sweep,n128,p8,fft2,coalesce=1,load16|p99_us",
        "serve|load_sweep,n128,p8,fft2,coalesce=1,load16|tps",
    }
    # split_key inverts the format even with '|'-free configs
    for key in keys:
        section, config, metric = h.split_key(key)
        assert f"{section}|{config}|{metric}" == key


def test_untracked_rows_contribute_nothing():
    assert h.row_metrics({"bench": "moe", "measured_us": 1.0}) == []
    assert h.row_metrics({"bench": "fft2", "n": 1, "p": 1}) == []  # no value
    assert h.row_metrics("not a dict") == []


def test_snapshot_from_bench_prefers_meta_then_overrides():
    doc = {
        "schema": 2,
        "meta": {"commit": "abc", "device_kind": "cpu",
                 "timestamp": "t0", "planner_score": {"groups": 14}},
        "rows": [
            {"bench": "fft2", "n": 32, "p": 2, "backend": "scatter",
             "measured_us": 5.0},
            {"bench": "fft2", "n": 32, "p": 2, "backend": "alltoall",
             "measured_us": 4.0},
        ],
    }
    snap = h.snapshot_from_bench(doc)
    assert (snap["commit"], snap["device_kind"], snap["timestamp"]) == (
        "abc", "cpu", "t0")
    assert snap["planner_score"] == {"groups": 14}
    assert snap["sections"] == {"fft2": 2}
    assert len(snap["metrics"]) == 2
    over = h.snapshot_from_bench(doc, commit="xyz", timestamp="t1")
    assert (over["commit"], over["timestamp"]) == ("xyz", "t1")
    bare = h.snapshot_from_bench({"rows": []})
    assert bare["commit"] == "unknown" and bare["metrics"] == {}


def test_ledger_roundtrip_skips_malformed_lines(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    assert h.read_history(path) == []  # missing file = empty history
    h.append_snapshot(path, _snap({"a|b|measured_us": 1.0}))
    with open(path, "a") as f:
        f.write("{corrupt\n")
        f.write('"not a dict"\n')
        f.write("\n")
    h.append_snapshot(path, _snap({"a|b|measured_us": 2.0}, commit="c1"))
    hist = h.read_history(path)
    assert [s["commit"] for s in hist] == ["c0", "c1"]
    assert h.history_values(hist, "a|b|measured_us") == [1.0, 2.0]


# ---------------------------------------------------------------------------
# Noise-aware detection
# ---------------------------------------------------------------------------

KEY = "fft2|n256,p8,scatter|measured_us"


def _history_of(values):
    return [_snap({KEY: v}, commit=f"c{i}") for i, v in enumerate(values)]


def test_detector_flags_2x_slowdown_but_not_mad_jitter():
    # synthetic noisy trajectory around 100us (+-3% jitter)
    base = [100.0, 103.0, 97.0, 101.0, 99.0, 102.0, 98.0, 100.0]
    hist = _history_of(base)
    bad = h.detect_regressions(hist, _snap({KEY: 200.0}))
    assert len(bad) == 1
    f = bad[0]
    assert (f["section"], f["config"], f["metric"]) == (
        "fft2", "n256,p8,scatter", "measured_us")
    assert f["ratio"] == pytest.approx(2.0, rel=0.05)
    # jitter at the trajectory's own MAD scale stays quiet
    assert h.detect_regressions(hist, _snap({KEY: 104.0})) == []
    # ...and a speedup never trips a time-like gate
    assert h.detect_regressions(hist, _snap({KEY: 50.0})) == []


def test_detector_needs_both_sigma_band_and_relative_floor():
    # near-zero MAD history: the min_ratio floor is what guards against
    # flagging a 1.2x wobble that is statistically "many sigmas"
    hist = _history_of([100.0] * 8)
    assert h.detect_regressions(hist, _snap({KEY: 120.0})) == []
    assert h.detect_regressions(hist, _snap({KEY: 151.0}))
    # wildly noisy history: the sigma band dominates the 1.5x floor
    noisy = _history_of([100.0, 300.0, 80.0, 250.0, 90.0, 280.0, 110.0, 260.0])
    assert h.detect_regressions(noisy, _snap({KEY: 300.0})) == []


def test_detector_min_snapshots_guard():
    hist = _history_of([100.0, 100.0])  # below the default guard of 3
    assert h.detect_regressions(hist, _snap({KEY: 1000.0})) == []
    hist = _history_of([100.0, 100.0, 100.0])
    assert h.detect_regressions(hist, _snap({KEY: 1000.0}))
    # explicit guard wins
    assert h.detect_regressions(hist, _snap({KEY: 1000.0}), min_snapshots=4) == []


def test_detector_throughput_direction_mirrors():
    tkey = "serve|load_sweep,n128,p8,fft2,coalesce=1,load16|tps"
    hist = [_snap({tkey: v}) for v in [500.0, 510.0, 490.0, 505.0]]
    drop = h.detect_regressions(hist, _snap({tkey: 200.0}))
    assert len(drop) == 1 and drop[0]["ratio"] > 2.0
    assert h.detect_regressions(hist, _snap({tkey: 520.0})) == []  # faster is fine
    assert h.detect_regressions(hist, _snap({tkey: 480.0})) == []  # jitter is fine


def test_detector_rolling_window_forgets_ancient_history():
    # 8 old slow points, then 8 recent fast ones: the k=8 window must
    # judge against the recent regime only
    hist = _history_of([1000.0] * 8 + [100.0] * 8)
    assert h.detect_regressions(hist, _snap({KEY: 210.0}), k=8)
    assert h.detect_regressions(hist, _snap({KEY: 210.0}), k=16) == []


def test_findings_sorted_worst_first():
    k2 = "real|n256,p8,r2c,scatter|measured_us"
    hist = [_snap({KEY: 100.0, k2: 10.0}, commit=f"c{i}") for i in range(4)]
    bad = h.detect_regressions(hist, _snap({KEY: 200.0, k2: 100.0}))
    assert [f["metric"] for f in bad] == ["measured_us", "measured_us"]
    assert bad[0]["key"] == k2  # 10x outranks 2x


# ---------------------------------------------------------------------------
# regress.py CLI (gate semantics end-to-end)
# ---------------------------------------------------------------------------


def _baseline_doc(us):
    return {
        "schema": 2,
        "meta": {"commit": "head", "device_kind": "cpu", "timestamp": "t"},
        "rows": [{"bench": "fft2", "n": 256, "p": 8, "backend": "scatter",
                  "measured_us": us, "device_kind": "cpu"}],
    }


def _write_case(tmp_path, history_us, baseline_us):
    from benchmarks import regress

    hist_p = str(tmp_path / "BENCH_history.jsonl")
    base_p = str(tmp_path / "BENCH_fft.json")
    for i, v in enumerate(history_us):
        h.append_snapshot(hist_p, _snap({KEY: v}, commit=f"c{i}"))
    with open(base_p, "w") as f:
        json.dump(_baseline_doc(baseline_us), f)
    return regress, hist_p, base_p


def test_regress_check_fails_naming_section_and_config(tmp_path, capsys):
    regress, hist_p, base_p = _write_case(
        tmp_path, [100.0, 101.0, 99.0, 100.0], 250.0)
    rc = regress.main(["--history", hist_p, "--baseline", base_p, "--check"])
    assert rc == 1
    err = capsys.readouterr().err
    assert "(fft2, n256,p8,scatter) measured_us" in err
    assert "vs median" in err


def test_regress_check_passes_within_noise(tmp_path, capsys):
    regress, hist_p, base_p = _write_case(
        tmp_path, [100.0, 101.0, 99.0, 100.0], 102.0)
    rc = regress.main(["--history", hist_p, "--baseline", base_p, "--check"])
    assert rc == 0
    assert "regress OK" in capsys.readouterr().out


def test_regress_check_fresh_ledger_never_false_fails(tmp_path, capsys):
    regress, hist_p, base_p = _write_case(tmp_path, [], 9999.0)
    rc = regress.main(["--history", hist_p, "--baseline", base_p, "--check"])
    assert rc == 0
    assert "below the 3-snapshot guard" in capsys.readouterr().out


def test_regress_append_grows_ledger(tmp_path):
    regress, hist_p, base_p = _write_case(tmp_path, [100.0], 100.0)
    rc = regress.main(["--history", hist_p, "--baseline", base_p, "--append"])
    assert rc == 0
    hist = h.read_history(hist_p)
    assert len(hist) == 2
    assert hist[-1]["commit"] == "head"  # from the baseline's stamped meta


def test_regress_table_renders_without_check(tmp_path, capsys):
    regress, hist_p, base_p = _write_case(tmp_path, [100.0, 110.0], 105.0)
    rc = regress.main(["--history", hist_p, "--baseline", base_p])
    assert rc == 0
    out = capsys.readouterr().out
    assert KEY in out and "median" in out


def test_committed_ledger_and_baseline_pass_the_gate():
    """The repo's own artifacts must satisfy the CI fast-job gate."""
    import os

    from benchmarks import regress

    rc = regress.main([
        "--history", os.path.join(REPO, "BENCH_history.jsonl"),
        "--baseline", os.path.join(REPO, "BENCH_fft.json"),
        "--check",
    ])
    assert rc == 0


# ---------------------------------------------------------------------------
# Persisted calibration
# ---------------------------------------------------------------------------


def test_record_and_lookup_calibration_per_backend_class():
    pooled = CommParams(alpha_s=2e-6, beta_bytes_s=5e10)
    per = {"scatter": CommParams(alpha_s=4e-6, beta_bytes_s=2e10)}
    planner.record_calibration("cpu", pooled, n=10, backends=per)
    got = planner.calibration_for("cpu")
    assert got.alpha_s == pytest.approx(2e-6)
    sub = planner.calibration_for("cpu", "scatter")
    assert sub.alpha_s == pytest.approx(4e-6)
    # unknown backend class falls back to the pooled fit
    assert planner.calibration_for("cpu", "alltoall").alpha_s == pytest.approx(2e-6)
    assert planner.calibration_for("tpu") is None


def test_record_calibration_merges_count_weighted():
    planner.record_calibration("cpu", CommParams(alpha_s=1e-6, beta_bytes_s=1e10), n=1)
    planner.record_calibration("cpu", CommParams(alpha_s=3e-6, beta_bytes_s=3e10), n=3)
    cell = planner.calibration_cell("cpu")
    assert cell["n"] == 4
    assert cell["alpha_s"] == pytest.approx((1e-6 + 3 * 3e-6) / 4)


def test_calibration_survives_wisdom_roundtrip(tmp_path):
    planner.record_calibration(
        "cpu", CommParams(alpha_s=2e-6, beta_bytes_s=5e10), n=7, source="bench_fit",
        backends={"scatter": CommParams(alpha_s=4e-6, beta_bytes_s=2e10)},
    )
    path = str(tmp_path / "WISDOM.json")
    planner.export_wisdom(path)
    with open(path) as f:
        doc = json.load(f)
    assert doc["calibration"]["cpu"]["source"] == "bench_fit"
    planner.forget_calibration()
    assert planner.calibration_for("cpu") is None
    # a calibration-only wisdom file imports fine (0 entries)
    assert planner.import_wisdom(path) == 0
    got = planner.calibration_for("cpu", "scatter")
    assert got.alpha_s == pytest.approx(4e-6)


def test_ensure_calibrated_runs_once_per_device_kind():
    mesh = make_mesh_1d(1)
    calls = []

    def sweep(m_bytes):
        calls.append(m_bytes)
        return 2 * 1e-6 + 2 * m_bytes / 1e11  # alpha=1us beta=100GB/s

    p1 = planner.ensure_calibrated(mesh, timer=sweep)
    assert p1.alpha_s == pytest.approx(1e-6, rel=0.05)
    n = len(calls)
    assert n >= 2
    p2 = planner.ensure_calibrated(mesh, timer=sweep)  # cached: no re-sweep
    assert len(calls) == n
    assert p2.alpha_s == pytest.approx(p1.alpha_s)
    planner.ensure_calibrated(mesh, timer=sweep, force=True)
    assert len(calls) > n


def test_auto_calibrate_switch_and_failure_memo():
    # suite env pins REPRO_AUTO_CALIBRATE=0 (conftest)
    assert not planner.auto_calibrate_enabled()
    planner.set_auto_calibrate(True)
    try:
        assert planner.auto_calibrate_enabled()
    finally:
        planner.set_auto_calibrate(None)
    assert not planner.auto_calibrate_enabled()


def test_default_params_plan_prices_with_stored_calibration():
    mesh = make_mesh_1d(1)
    before = plan_fft((32, 32), mesh)
    planner.record_calibration(
        planner.device_kind(mesh), CommParams(alpha_s=9e-5, beta_bytes_s=1e9)
    )
    after = plan_fft((32, 32), mesh)
    assert after.params.alpha_s == pytest.approx(9e-5)
    assert before.params.alpha_s != after.params.alpha_s
    # explicit params still win over the store
    pinned = plan_fft((32, 32), mesh, params=CommParams(alpha_s=5e-6))
    assert pinned.params.alpha_s == pytest.approx(5e-6)


# ---------------------------------------------------------------------------
# Decision provenance (Plan.why / selection_channel)
# ---------------------------------------------------------------------------


def _race_table(winner="scatter", loser_us=9.0):
    from repro.core import backends

    table = {n: loser_us for n in backends.available()
             if backends.get(n).supports(1)}
    table[winner] = 1.0
    return table


def test_channel_pinned_and_model_argmin():
    mesh = make_mesh_1d(1)
    pinned = plan_fft((32, 32), mesh, backend="scatter")
    assert pinned.selection_channel == "pinned"
    auto = plan_fft((32, 32), mesh, backend="auto")
    assert auto.selection_channel == "model-argmin"
    for plan in (pinned, auto):
        why = plan.why()
        assert why["channel"] == plan.selection_channel
        assert why["backend"] == plan.backend
        assert why["timings"]  # non-empty decision table
        assert plan.why_text().startswith("why: backend=")


def test_channel_measured_race_then_wisdom_hit():
    mesh = make_mesh_1d(1)
    timer = _fake_timer(_race_table())
    p1 = plan_fft((32, 32), mesh, planner="measure", timer=timer)
    assert p1.selection_channel == "measured-race"
    assert p1.why()["timings_kind"] == "measured"
    assert p1.why()["wisdom_key"]
    p2 = plan_fft((32, 32), mesh, planner="measure", timer=timer)
    assert p2.selection_channel == "wisdom-hit"
    assert p2.backend == p1.backend
    assert "wisdom-hit" in p2.why_text()


def test_channel_observed_overlay_flips_argmin():
    mesh = make_mesh_1d(1)
    timer = _fake_timer(_race_table(winner="scatter"))
    p1 = plan_fft((32, 32), mesh, planner="measure", timer=timer)
    assert p1.backend == "scatter"
    # production telemetry says the race winner is actually slow and a
    # rival is fast: fold enough observations to flip the argmin
    for _ in range(5):
        planner.record_observed(p1, 50e-6, backend="scatter")
        planner.record_observed(p1, 0.5e-6, backend="alltoall")
    p2 = plan_fft((32, 32), mesh, planner="measure", timer=timer)
    assert p2.backend == "alltoall"
    assert p2.selection_channel == "observed-overlay"
    assert "observed-overlay" in p2.why_text()
    # the drifted entry is flagged stale for operators
    report = planner.wisdom_report()
    assert any(row["stale"] for row in report)


def test_why_reports_calibration_constants():
    mesh = make_mesh_1d(1)
    planner.record_calibration(
        planner.device_kind(mesh),
        CommParams(alpha_s=7e-6, beta_bytes_s=3e10),
        source="bench_fit",
    )
    plan = plan_fft((32, 32), mesh, backend="auto")
    cal = plan.why()["calibration"]
    assert cal["calibrated"] and cal["source"] == "bench_fit"
    assert cal["alpha_s"] == pytest.approx(7e-6)
    assert "bench_fit" in plan.why_text()


def test_wisdom_report_quiet_entry_not_stale():
    mesh = make_mesh_1d(1)
    timer = _fake_timer(_race_table())
    p1 = plan_fft((32, 32), mesh, planner="measure", timer=timer)
    planner.record_observed(p1, 1.1)  # matches the 1.0s race closely
    (row,) = planner.wisdom_report()
    assert not row["stale"]
    assert row["observed_n"] == 1
    assert row["max_drift"] == pytest.approx(1.1, rel=0.05)
