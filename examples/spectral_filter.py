"""Scientific-compute example: distributed spectral low-pass filtering of
a 3-D field using the planned collective-backend FFT (paper's application
class: multi-dimensional FFT on a partitioned domain).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/spectral_filter.py
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, "src")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import plan_fft
from repro.core.compat import make_mesh_1d


def main():
    mesh = make_mesh_1d(len(jax.devices()))
    d = 64
    rng = np.random.default_rng(0)
    # smooth field + high-frequency noise
    grid = np.stack(np.meshgrid(*[np.linspace(0, 2 * np.pi, d)] * 3, indexing="ij"))
    smooth = np.sin(grid[0]) * np.cos(2 * grid[1]) + 0.5 * np.sin(3 * grid[2])
    field = (smooth + 0.5 * rng.standard_normal((d, d, d))).astype(np.complex64)

    # one plan, validated once; both directions reuse its cached executables
    plan = plan_fft((d, d, d), mesh, ndim=3, backend="scatter")
    spec = plan.execute(jnp.asarray(field))
    # low-pass mask (keep |k| < d/8 per axis)
    freqs = np.fft.fftfreq(d) * d
    keep = (np.abs(freqs) < d / 8)
    mask = keep[:, None, None] & keep[None, :, None] & keep[None, None, :]
    filt = spec * jnp.asarray(mask)
    back = plan.inverse(filt)

    residual = np.asarray(jnp.real(back)) - smooth
    noise_in = field.real - smooth
    print(f"noise std before: {noise_in.std():.3f}  after filter: {residual.std():.3f}")
    assert residual.std() < 0.45 * noise_in.std()
    print("OK: distributed spectral filter removed the high-frequency noise")


if __name__ == "__main__":
    main()
