"""Batched serving example: slot-based continuous batching with ragged
prompts on a reduced model.

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys
import time

import numpy as np

sys.path.insert(0, "src")

import jax

from repro.configs import ServeConfig, get_config
from repro.models import Model
from repro.serve import ServeEngine


def main():
    cfg = get_config("gemma2-9b", reduced=True)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, ServeConfig(max_batch=4, max_seq=96))
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
        for n in rng.integers(4, 24, size=10)
    ]
    t0 = time.perf_counter()
    results = engine.run(prompts, max_new=24)
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in results.values())
    print(f"served {len(results)} ragged requests / {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s on CPU)")
    for uid in sorted(results)[:3]:
        print(f"  req {uid} -> {results[uid][:10]}...")


if __name__ == "__main__":
    main()
