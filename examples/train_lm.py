"""End-to-end training driver example: train a ~100M-param qwen-family
model for a few hundred steps on synthetic data, with checkpointing and
failure recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(CPU note: ~100M params on one core is slow; --tiny uses the reduced
config so the example completes in ~a minute. The full invocation is the
same code path the cluster launcher uses.)
"""

import argparse
import dataclasses
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import TrainConfig, get_config
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLM
from repro.models import Model
from repro.runtime import StepMonitor, run_with_recovery
from repro.train import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    if args.tiny:
        cfg = get_config("qwen2.5-32b", reduced=True)
        batch, seq = 8, 64
    else:
        # ~100M params: 12L x 640d, qwen-family
        cfg = dataclasses.replace(
            get_config("qwen2.5-32b"),
            num_layers=12, d_model=640, num_heads=10, num_kv_heads=2,
            d_ff=1728, vocab_size=32064,
        )
        batch, seq = 8, 256
    n = cfg.param_count()
    print(f"arch={cfg.name} params~{n/1e6:.1f}M batch={batch} seq={seq}")

    model = Model(cfg)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=20, total_steps=args.steps)
    ds = SyntheticLM(DataConfig(cfg.vocab_size, seq, batch, seed=0, noise=0.02))
    ckpt = CheckpointManager(args.ckpt, keep=2)
    mon = StepMonitor()

    def loop(resume):
        state, _ = init_train_state(model, jax.random.PRNGKey(0), tcfg)
        start = ckpt.latest_step() or 0
        if start:
            state = ckpt.restore(start, state)
            print(f"resumed at step {start}")
        step_fn = jax.jit(make_train_step(model, tcfg, None), donate_argnums=(0,))
        import jax.numpy as jnp

        for s in range(start, args.steps):
            b = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
            mon.start()
            state, m = step_fn(state, b)
            st = mon.stop(tokens=batch * seq)
            if s % 20 == 0 or s == args.steps - 1:
                print(f"step {s:4d} loss {float(m['loss']):.4f} "
                      f"lr {float(m['lr']):.2e} {mon.tokens_per_sec:.0f} tok/s")
            if (s + 1) % 100 == 0:
                ckpt.save(s + 1, state)
        ckpt.wait()

    run_with_recovery(loop, max_restarts=1)
    print("straggler report:", mon.straggler_report())


if __name__ == "__main__":
    main()
