"""Quickstart: distributed 2-D FFT with switchable collective strategies.

Run (any machine; forces 8 host devices for a visible mesh):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import AxisType

from repro.core import FFTConfig, fft2, ifft2, make_plan


def main():
    mesh = jax.make_mesh((len(jax.devices()),), ("model",), axis_types=(AxisType.Auto,))
    print(f"mesh: {dict(mesh.shape)}")

    rng = np.random.default_rng(0)
    n = 512
    x = jnp.asarray(
        (rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))).astype(np.complex64)
    )
    ref = np.fft.fft2(np.asarray(x))

    # the paper's comparison: one synchronized all-to-all vs N scatters
    for strategy in ("alltoall", "scatter", "bisection", "xla_auto"):
        y = fft2(x, mesh, "model", FFTConfig(strategy=strategy))
        err = float(jnp.abs(jnp.asarray(y) - jnp.asarray(ref.T)).max())
        print(f"  fft2[{strategy:9s}] max err vs numpy: {err:.2e}")

    # beyond-paper: fold the second-dimension DFT into the scatter ring
    y = fft2(x, mesh, "model", FFTConfig(strategy="scatter", fuse_dft=True))
    print(f"  fft2[scatter+fused-dft] err: {float(jnp.abs(y - ref.T).max()):.2e}")

    # plans (FFTW-style), roundtrip
    plan = make_plan((n, n), mesh, strategy="scatter")
    z = ifft2(plan.execute(x), mesh, "model", FFTConfig(strategy="scatter"))
    print(f"  ifft2(fft2(x)) roundtrip err: {float(jnp.abs(z - x).max()):.2e}")
    print(f"  per-device pencil exchange: {plan.comm_bytes()/2**20:.1f} MiB")


if __name__ == "__main__":
    main()
