"""Quickstart: distributed 2-D FFT through the plan/executor front-end
with pluggable collective backends (the HPX parcelport analogue).

Run (any machine; forces 8 host devices for a visible mesh):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import backends, plan_fft
from repro.core.compat import make_mesh_1d


def main():
    mesh = make_mesh_1d(len(jax.devices()))
    print(f"mesh: {dict(mesh.shape)}")

    rng = np.random.default_rng(0)
    n = 512
    x = jnp.asarray(
        (rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))).astype(np.complex64)
    )
    ref = np.fft.fft2(np.asarray(x))

    # the paper's comparison, over every registered backend (parcelport axis)
    for name in backends.available():
        if not backends.get(name).supports(mesh.shape["model"]):
            continue
        plan = plan_fft((n, n), mesh, backend=name)
        y = plan.execute(x)
        err = float(jnp.abs(jnp.asarray(y) - jnp.asarray(ref.T)).max())
        print(f"  fft2[{name:12s}] max err vs numpy: {err:.2e}")

    # the pipelined overlap executor: fused (default) vs unfused, and an
    # n_chunks-decoupled stream (the paper's per-chunk-compute overlap)
    plan_unfused = plan_fft((n, n), mesh, backend="scatter", pipeline=False)
    plan_fused = plan_fft((n, n), mesh, backend="scatter")  # pipeline="auto"
    plan_stream = plan_fft((n, n), mesh, backend="scatter", pipeline=32)
    y = plan_fused.execute(x)
    print(f"  fft2[scatter fused] err: {float(jnp.abs(y - ref.T).max()):.2e}  "
          f"(n_chunks={plan_stream.n_chunks} stream err: "
          f"{float(jnp.abs(plan_stream.execute(x) - ref.T).max()):.2e})")
    model_f = plan_fused.predict(fused=True)["scatter"]
    model_u = plan_unfused.predict(fused=False)["scatter"]
    print(f"  model: fused {model_f*1e6:.1f}us vs unfused {model_u*1e6:.1f}us "
          f"(overlap hides the stage compute)")

    # backend="auto": the alpha-beta cost model picks before anything runs
    plan = plan_fft((n, n), mesh, backend="auto")
    ranking = sorted(plan.predict().items(), key=lambda kv: kv[1])
    print(f"  auto -> {plan.backend!r}  (model ranking: "
          + ", ".join(f"{k}={v*1e6:.1f}us" for k, v in ranking) + ")")

    # decision provenance: every plan can explain WHY its backend won --
    # which channel decided (pinned / model-argmin / measured-race /
    # wisdom-hit / observed-overlay), over which timing table, under
    # which calibration constants (run.py --explain dumps the same)
    print("  " + plan.why_text().replace("\n", "\n  "))

    # planner="measure": FFTW_MEASURE -- time every backend on THIS mesh,
    # pick the measured argmin, remember it as wisdom
    measured = plan_fft((n, n), mesh, planner="measure")
    timed = sorted(measured.measured.items(), key=lambda kv: kv[1])
    print(f"  measure -> {measured.backend!r}  (measured: "
          + ", ".join(f"{k}={v*1e6:.0f}us" for k, v in timed) + ")")
    again = plan_fft((n, n), mesh, planner="measure")
    print(f"  second identical plan: wisdom_hit={again.wisdom_hit} (no re-measurement)")
    wisdom_path = "/tmp/fft_wisdom.json"
    from repro.core import export_wisdom
    export_wisdom(wisdom_path)
    print(f"  wisdom exported to {wisdom_path} (import_wisdom() restores it)")

    # calibrate alpha/beta on the real fabric and estimate with those
    from repro.core import CommParams
    prm = CommParams.calibrate(mesh, sizes=(4096, 65536, 1048576), iters=3)
    cal = plan_fft((n, n), mesh, params=prm)
    print(f"  calibrated alpha={prm.alpha_s*1e6:.1f}us beta={prm.beta_bytes_s/1e9:.1f}GB/s"
          f" -> estimate picks {cal.backend!r}")

    # pencil decomposition: a 2-D process grid, one backend PER grid axis
    # (the 2-D analogue of the parcelport switch; see README)
    from repro.core.compat import make_mesh
    from repro.core.grid import auto_grid_shape

    pr, pc = auto_grid_shape(len(jax.devices()))
    if pr > 1:
        gmesh = make_mesh((pr, pc), ("rows", "cols"))
        n3 = 8 * pr * pc  # divisible by both grid dims on every axis
        x3 = jnp.asarray(
            (rng.standard_normal((n3,) * 3) + 1j * rng.standard_normal((n3,) * 3))
            .astype(np.complex64)
        )
        pplan = plan_fft((n3,) * 3, gmesh, ndim=3, decomp="pencil")
        y3 = pplan.execute(x3)
        ref3 = np.fft.fftn(np.asarray(x3)).transpose(2, 1, 0)
        print(f"  pencil fft3 on {pr}x{pc} grid -> row={pplan.backend_row!r} "
              f"col={pplan.backend_col!r}, err {float(jnp.abs(y3 - ref3).max()):.2e}")

    # real input? plan_fft(real=True) ships only the Hermitian-truncated
    # N//2+1 payload -- about half the wire bytes of the c2c plan
    xr = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    rplan = plan_fft((n, n), mesh, real=True)
    yr = rplan.execute(xr)                      # distributed rfftn
    h = rplan.hermitian_len
    ref_r = np.fft.rfft2(np.asarray(xr))
    err_r = float(jnp.abs(yr[:h] - ref_r.T).max())  # transposed half spectrum
    back = rplan.inverse(yr)                    # distributed irfftn, real out
    print(f"  rfft2[real=True] err vs numpy.rfft2: {err_r:.2e}; "
          f"roundtrip {float(jnp.abs(back - xr).max()):.2e}")
    print(f"  wire bytes: c2c {plan.comm_bytes()/2**10:.0f} KiB vs "
          f"r2c {rplan.comm_bytes()/2**10:.0f} KiB "
          f"(ratio {rplan.comm_bytes()/plan.comm_bytes():.2f}; "
          f"H={h} padded to {rplan.padded_hermitian_len})")

    # spectral application layer: a Poisson solve through the real plan --
    # decomposition/backend/planner choices all flow through the Plan
    from repro.apps import solve_poisson

    ns = 64
    xs = np.arange(ns) * 2 * np.pi / ns
    X, Y = np.meshgrid(xs, xs, indexing="ij")
    u_true = np.sin(X) * np.cos(2 * Y)
    f = jnp.asarray((-5.0 * u_true).astype(np.float32))  # f = laplacian(u)
    pplan2 = plan_fft((ns, ns), mesh, real=True)
    u = solve_poisson(f, pplan2)
    print(f"  poisson[plan_fft(real=True)] max |u - u_true|: "
          f"{float(jnp.abs(u - u_true).max()):.2e}")

    # spectral serving: many small concurrent requests through one
    # engine -- same-shape requests coalesce into one stacked batched
    # execution, plans come from a warm LRU pool, dispatch is async
    # (futures; nothing blocks until .block()/.result())
    from repro.serve import SpectralEngine

    eng = SpectralEngine(mesh, max_batch=8, max_wait_s=0.002)
    ns2 = 64
    rhs = jnp.asarray((-5.0 * u_true).astype(np.float32))
    reqs = [eng.submit("poisson", rhs, lengths=(2 * np.pi, 2 * np.pi))
            for _ in range(3)]
    reqs += [eng.submit("rfft", jnp.asarray(
        rng.standard_normal((ns2, ns2)).astype(np.float32))) for _ in range(4)]
    eng.drain()  # flush partial batches, wait for the device
    st = eng.stats()
    print(f"  serving: {st['requests']} reqs in {st['batches']} batches "
          f"(mean batch {st['mean_batch']:.1f}); "
          f"p50 {st['latency_s']['p50']*1e3:.1f}ms "
          f"p99 {st['latency_s']['p99']*1e3:.1f}ms; "
          f"pool hits/misses {st['pool']['hits']}/{st['pool']['misses']}")
    perr = float(jnp.abs(reqs[0].result() - reqs[2].result()).max())
    print(f"  coalesced poisson requests agree to {perr:.1e}; warm engines "
          f"(wisdom=PATH) skip plan_fft on the request path entirely")

    # chaos demo: poison one coalesced request with a deterministic
    # FaultPlan -- the batch splits, siblings answer correctly, the
    # poisoned future quarantines and re-raises; nothing else notices
    from repro.runtime import FaultPlan, RetryPolicy

    chaos = SpectralEngine(mesh, max_batch=4, max_wait_s=100.0,
                           retry=RetryPolicy(max_retries=0))
    xc = [jnp.asarray((rng.standard_normal((ns2, ns2))
                       + 1j * rng.standard_normal((ns2, ns2))).astype(np.complex64))
          for _ in range(4)]
    chaos.set_faults(FaultPlan.error(match="Exchange", times=2))
    cfuts = [chaos.submit("fft", xi) for xi in xc]
    chaos.drain()  # quarantined failures are isolated to their futures
    survivors = [f for f in cfuts if not f.failed()]
    cm = chaos.metrics()
    print(f"  chaos: {len(survivors)}/4 coalesced requests survived an "
          f"injected Exchange fault (errors={cm['errors']} "
          f"batch_splits={cm['batch_splits']} quarantined={cm['quarantined']}); "
          f"breakers degrade repeat offenders to xla_auto "
          f"(degraded_dispatches={cm['degraded_dispatches']})")

    # one plan, cached executable, forward + inverse roundtrip
    z = plan.inverse(plan.execute(x))
    print(f"  ifft2(fft2(x)) roundtrip err: {float(jnp.abs(z - x).max()):.2e}")
    print(f"  per-device exchange traffic per transform: {plan.comm_bytes()/2**20:.1f} MiB "
          f"(dtype-aware: c128 would be {plan.comm_bytes(jnp.complex128)/2**20:.1f} MiB)")
    print(f"  executables compiled: {plan.compiles} (repeat executes hit the cache)")


if __name__ == "__main__":
    main()
